"""Guard the committed BENCH_*.json speedups against silent regression.

Re-measures the PR-1 batched-pricing engine, the PR-2 vectorized
simulator, the PR-3/4 serve engine (continuous-vs-static batching at
equal slots, solo-bitwise outputs), and the PR-5 paged KV layout
(bitwise agreement with the contiguous oracle + the iso-memory
shared-prefix concurrency win) on reduced budgets and compares against
the committed BENCH_mapper.json / BENCH_simulate.json / BENCH_serve.json
claims:

    PYTHONPATH=src python -m benchmarks.check_regress [--full] [--tol 0.15]

The tolerance is deliberately generous (default: fresh speedup must reach
15% of the committed one; the serve ratio, being O(1.3-2x), uses its own
``--serve-tol`` floor fraction) because CI runners are noisy and shared —
the guard exists to catch the engine quietly falling back to a scalar path
or losing an order of magnitude, not 2x jitter.  ``--full`` additionally
re-runs the end-to-end optimize_network sweep (minutes).  The fresh runs
re-assert correctness against their oracles (bit-identity for the
simulator/pricer, batched-equals-solo bitwise sampling for serving), so
correctness rot fails the guard too.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _load(path: str) -> dict:
    if not os.path.exists(path):
        sys.exit(f"missing committed benchmark file: {path}")
    with open(path) as f:
        return json.load(f)


def _check(name: str, committed: float, fresh: float, tol: float) -> bool:
    floor = committed * tol
    ok = fresh >= floor
    status = "ok  " if ok else "FAIL"
    print(
        f"[{status}] {name}: committed {committed:8.1f}x   "
        f"fresh {fresh:8.1f}x   floor {floor:6.1f}x"
    )
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tol",
        type=float,
        default=0.15,
        help="fresh speedup must reach this fraction of the committed one",
    )
    ap.add_argument(
        "--full",
        action="store_true",
        help="also re-run the end-to-end optimize_network sweep (minutes)",
    )
    ap.add_argument(
        "--serve-tol",
        type=float,
        default=0.5,
        help="fresh continuous-vs-static ratio must reach this fraction "
        "of the committed one (serve ratios are O(1.3-2x), so the "
        "generic --tol would never trip)",
    )
    ap.add_argument("--mapper-json", default="BENCH_mapper.json")
    ap.add_argument("--simulate-json", default="BENCH_simulate.json")
    ap.add_argument("--serve-json", default="BENCH_serve.json")
    args = ap.parse_args()

    from benchmarks import perf_compare, serve_bench

    mapper = _load(args.mapper_json)
    simulate = _load(args.simulate_json)
    serve = _load(args.serve_json)
    if not simulate.get("bit_identical", False):
        sys.exit("committed BENCH_simulate.json lost bit_identical=true")
    if not mapper["optimize_network"].get("identical_best", False):
        sys.exit("committed BENCH_mapper.json lost identical_best=true")
    if not serve.get("solo_outputs_identical", False):
        sys.exit("committed BENCH_serve.json lost solo_outputs_identical=true")
    if serve["attention_ab"]["flash_vs_oracle_speedup"] < 1.0:
        sys.exit(
            "committed BENCH_serve.json: flash-decoding slower than the "
            "masked-oracle attend path"
        )
    # PR 5: the paged KV layout must stay bitwise-agreeing with the
    # contiguous oracle, and the shared-prefix workload must keep its
    # iso-memory concurrency win (this ratio is deterministic scheduling,
    # not timing, so no noise tolerance applies)
    if not serve["paged"]["agreement"]["bitwise_identical"]:
        sys.exit("committed BENCH_serve.json: paged != contiguous bitwise")
    if not serve["paged"]["shared_prefix"]["bitwise_identical"]:
        sys.exit(
            "committed BENCH_serve.json: shared-prefix paged outputs "
            "diverged from the contiguous oracle"
        )
    if serve["paged"]["shared_prefix"]["admitted_concurrency_ratio"] < 1.5:
        sys.exit(
            "committed BENCH_serve.json: shared-prefix paged concurrency "
            "win below the 1.5x floor"
        )

    failures = []

    # PR 1: batched pricing rate (asserts batched == scalar internally)
    fresh_rate = perf_compare.bench_pricing_rate()
    if not _check(
        "mapper pricing",
        mapper["pricing"]["speedup"],
        fresh_rate["speedup"],
        args.tol,
    ):
        failures.append("mapper pricing")

    # PR 2: vectorized simulator (raises if it diverges from the odometer)
    with tempfile.TemporaryDirectory() as tmp:
        fresh_sim = perf_compare.run_simulate(os.path.join(tmp, "sim.json"), n=16)
    if not _check("simulate", simulate["speedup"], fresh_sim["speedup"], args.tol):
        failures.append("simulate")

    # PR 3/4: continuous-vs-static serve throughput at equal slots, on a
    # reduced workload; the fresh run re-asserts batched-equals-solo
    # bitwise sampling internally
    fresh_serve = serve_bench.run(
        slots=serve["slots"],
        max_len=serve["max_len"],
        n_requests=8,
        repeats=2,
        out_path=None,
        scaling=False,
        ab=False,
        paged=False,
    )
    if not fresh_serve["solo_outputs_identical"]:
        failures.append("serve solo-bitwise")
    if not _check(
        "serve continuous/static",
        serve["speedup_tokens_per_s"],
        fresh_serve["speedup_tokens_per_s"],
        args.serve_tol,
    ):
        failures.append("serve continuous/static")

    # PR 5: fresh paged-vs-contiguous differential on a reduced workload.
    # Both gates are exact, not timing: the agreement bit is bitwise token
    # equality, and the concurrency ratio is deterministic scheduling.
    import jax

    from repro.arch.model_zoo import build
    from repro.configs.registry import get

    cfg = get(serve["arch"])
    params = build(cfg).init(jax.random.PRNGKey(0))
    fresh_paged = serve_bench.bench_paged(
        cfg,
        params,
        slots=2,
        seed=0,
        n_requests=6,
        shared_max_len=160,
        shared_prefix=96,
        shared_requests=8,
    )
    ok_agree = (
        fresh_paged["agreement"]["bitwise_identical"]
        and fresh_paged["shared_prefix"]["bitwise_identical"]
    )
    ratio = fresh_paged["shared_prefix"]["admitted_concurrency_ratio"]
    print(
        f"[{'ok  ' if ok_agree else 'FAIL'}] paged bitwise agreement; "
        f"[{'ok  ' if ratio >= 1.5 else 'FAIL'}] shared-prefix "
        f"concurrency {ratio:.2f}x (floor 1.5x)"
    )
    if not ok_agree:
        failures.append("paged bitwise agreement")
    if ratio < 1.5:
        failures.append("paged shared-prefix concurrency")

    if args.full:
        fresh_sweep = perf_compare.bench_network_sweep()
        if not fresh_sweep["identical_best"]:
            failures.append("sweep identical_best")
        if not _check(
            "optimize_network sweep",
            mapper["optimize_network"]["speedup"],
            fresh_sweep["speedup"],
            args.tol,
        ):
            failures.append("optimize_network sweep")

    if failures:
        sys.exit(f"benchmark regression: {', '.join(failures)}")
    print("bench-check: committed speedups hold")


if __name__ == "__main__":
    main()
