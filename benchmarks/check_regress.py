"""Guard the committed BENCH_*.json speedups against silent regression.

Re-measures the PR-1 batched-pricing engine, the PR-2 vectorized
simulator, the PR-3/4 serve engine (continuous-vs-static batching at
equal slots, solo-bitwise outputs), the PR-5 paged KV layout
(bitwise agreement with the contiguous oracle + the iso-memory
shared-prefix concurrency win), the PR-6 request-lifecycle fault
storm (zero leaked blocks, bitwise-stable survivors, preemptions all
recovered, survivor ITL p95 within 1.25x of the no-fault baseline),
the PR-7 crash-recovery drill (snapshot-on ITL p95 within 1.10x
of snapshot-off, restore+replay bitwise with zero mismatches and zero
leaked blocks), and the PR-8 unified-scheduler admission storm
(chunked prefill cuts interactive TTFT p95 >= 2x vs monolithic
admission while decoder ITL p95 stays within 1.15x of storm-free,
bitwise identical to the monolithic oracle with zero leaked blocks
and at least one mid-prefill lane preemption), and the PR-10 DSE
serve planner (cost-model top-1 config inside the measured top-3 of
the autotune grid, autotuned >= 1.0x the shipped default, plus fresh
plan determinism / cache round-trip / corrupt-entry re-search) on
reduced budgets and
compares against the committed BENCH_mapper.json /
BENCH_simulate.json / BENCH_serve.json claims:

    PYTHONPATH=src python -m benchmarks.check_regress [--full] [--tol 0.15]

The tolerance is deliberately generous (default: fresh speedup must reach
15% of the committed one; the serve ratio, being O(1.3-2x), uses its own
``--serve-tol`` floor fraction) because CI runners are noisy and shared —
the guard exists to catch the engine quietly falling back to a scalar path
or losing an order of magnitude, not 2x jitter.  ``--full`` additionally
re-runs the end-to-end optimize_network sweep (minutes).  The fresh runs
re-assert correctness against their oracles (bit-identity for the
simulator/pricer, batched-equals-solo bitwise sampling for serving), so
correctness rot fails the guard too.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _load(path: str) -> dict:
    if not os.path.exists(path):
        sys.exit(f"missing committed benchmark file: {path}")
    with open(path) as f:
        return json.load(f)


def _field(d: dict, path: str, src: str, regen: str):
    """Walk a dotted ``path`` into a committed BENCH json, exiting with
    the name of the first missing field (and the command that regenerates
    the file) instead of a bare KeyError traceback."""
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            sys.exit(
                f"{src} is missing field {path!r} (no {part!r}) — the "
                f"committed benchmark predates this check; regenerate it "
                f"with '{regen}'"
            )
        cur = cur[part]
    return cur


def _check(name: str, committed: float, fresh: float, tol: float) -> bool:
    floor = committed * tol
    ok = fresh >= floor
    status = "ok  " if ok else "FAIL"
    print(
        f"[{status}] {name}: committed {committed:8.1f}x   "
        f"fresh {fresh:8.1f}x   floor {floor:6.1f}x"
    )
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tol",
        type=float,
        default=0.15,
        help="fresh speedup must reach this fraction of the committed one",
    )
    ap.add_argument(
        "--full",
        action="store_true",
        help="also re-run the end-to-end optimize_network sweep (minutes)",
    )
    ap.add_argument(
        "--serve-tol",
        type=float,
        default=0.5,
        help="fresh continuous-vs-static ratio must reach this fraction "
        "of the committed one (serve ratios are O(1.3-2x), so the "
        "generic --tol would never trip)",
    )
    ap.add_argument("--mapper-json", default="BENCH_mapper.json")
    ap.add_argument("--simulate-json", default="BENCH_simulate.json")
    ap.add_argument("--serve-json", default="BENCH_serve.json")
    args = ap.parse_args()

    from benchmarks import perf_compare, serve_bench

    mapper = _load(args.mapper_json)
    simulate = _load(args.simulate_json)
    serve = _load(args.serve_json)
    mapper_f = lambda p: _field(mapper, p, args.mapper_json, "make bench-mapper")
    sim_f = lambda p: _field(simulate, p, args.simulate_json, "make bench-simulate")
    serve_f = lambda p: _field(serve, p, args.serve_json, "make bench-serve")
    if not simulate.get("bit_identical", False):
        sys.exit("committed BENCH_simulate.json lost bit_identical=true")
    if not mapper_f("optimize_network").get("identical_best", False):
        sys.exit("committed BENCH_mapper.json lost identical_best=true")
    if not serve.get("solo_outputs_identical", False):
        sys.exit("committed BENCH_serve.json lost solo_outputs_identical=true")
    if serve_f("attention_ab.flash_vs_oracle_speedup") < 1.0:
        sys.exit(
            "committed BENCH_serve.json: flash-decoding slower than the "
            "masked-oracle attend path"
        )
    # PR 5: the paged KV layout must stay bitwise-agreeing with the
    # contiguous oracle, and the shared-prefix workload must keep its
    # iso-memory concurrency win (this ratio is deterministic scheduling,
    # not timing, so no noise tolerance applies)
    if not serve_f("paged.agreement.bitwise_identical"):
        sys.exit("committed BENCH_serve.json: paged != contiguous bitwise")
    if not serve_f("paged.shared_prefix.bitwise_identical"):
        sys.exit(
            "committed BENCH_serve.json: shared-prefix paged outputs "
            "diverged from the contiguous oracle"
        )
    if serve_f("paged.shared_prefix.admitted_concurrency_ratio") < 1.5:
        sys.exit(
            "committed BENCH_serve.json: shared-prefix paged concurrency "
            "win below the 1.5x floor"
        )
    # PR 6: the fault-storm phase must show a leak-free, bitwise-stable
    # engine under cancellation/deadline/preemption fire, and survivors
    # must not be badly degraded (ITL p95 within 1.25x of the no-fault
    # baseline — the one timing gate here, measured as a median of paired
    # back-to-back runs to shed scheduler noise)
    storm = serve_f("fault_storm")
    if storm["leaked_blocks"] != 0:
        sys.exit(
            "committed BENCH_serve.json: fault storm leaked "
            f"{storm['leaked_blocks']} KV blocks"
        )
    if not storm["bitwise_survivors_match_baseline"]:
        sys.exit(
            "committed BENCH_serve.json: fault-storm survivors diverged "
            "from their unfaulted baseline outputs"
        )
    if storm["survivor_itl_p95_vs_baseline"] > 1.25:
        sys.exit(
            "committed BENCH_serve.json: fault-storm survivor ITL p95 "
            f"{storm['survivor_itl_p95_vs_baseline']:.2f}x the no-fault "
            "baseline (ceiling 1.25x)"
        )
    if storm["preemptions"] < 1 or storm["recovered"] < storm["preemptions"]:
        sys.exit(
            "committed BENCH_serve.json: fault storm must exercise "
            "preemption and recover every victim "
            f"(preemptions={storm['preemptions']}, "
            f"recovered={storm['recovered']})"
        )
    # PR 7: durability must stay near-free (snapshot-on ITL p95 within
    # 1.10x of snapshot-off — the one timing gate, checked against the
    # committed JSON like the storm ceiling above), and the kill/restore
    # drill must have replayed journaled tokens into bitwise-identical
    # survivors without leaking a block
    if serve_f("crash_recovery.overhead.snapshot_itl_p95_vs_off") > 1.10:
        sys.exit(
            "committed BENCH_serve.json: snapshot+journal ITL p95 "
            f"{serve_f('crash_recovery.overhead.snapshot_itl_p95_vs_off'):.2f}x "
            "the snapshot-off baseline (ceiling 1.10x)"
        )
    if serve_f("crash_recovery.recovery.tokens_replayed") < 1:
        sys.exit(
            "committed BENCH_serve.json: recovery drill replayed no "
            "journaled tokens — the crash landed after a drain, so the "
            "drill proved nothing"
        )
    if (
        serve_f("crash_recovery.recovery.replay_mismatches") != 0
        or not serve_f("crash_recovery.recovery.bitwise_survivors")
    ):
        sys.exit(
            "committed BENCH_serve.json: restored run diverged from the "
            "never-crashed oracle "
            f"(mismatches={serve_f('crash_recovery.recovery.replay_mismatches')})"
        )
    if serve_f("crash_recovery.recovery.leaked_blocks") != 0:
        sys.exit(
            "committed BENCH_serve.json: recovery drill leaked "
            f"{serve_f('crash_recovery.recovery.leaked_blocks')} KV blocks"
        )
    # PR 8: the unified scheduler's admission storm must keep its headline
    # trade — interactive TTFT p95 cut at least 2x vs monolithic admission
    # while the decode ring's ITL p95 stays within 1.15x of the storm-free
    # baseline (both timing gates read from the committed JSON, measured
    # against wall-clock arrivals on the machine that generated it) — and
    # its exact invariants: bitwise identity with the monolithic oracle,
    # zero leaked blocks, and at least one mid-prefill lane preemption
    # (the priority takeover path must actually fire under the storm)
    adm = serve_f("admission_storm")
    if not adm["bitwise_identical_to_monolithic"]:
        sys.exit(
            "committed BENCH_serve.json: chunked admission-storm outputs "
            "diverged from the monolithic oracle"
        )
    if adm["leaked_blocks"] != 0:
        sys.exit(
            "committed BENCH_serve.json: admission storm leaked "
            f"{adm['leaked_blocks']} KV blocks"
        )
    if adm["lane_preemptions"] < 1:
        sys.exit(
            "committed BENCH_serve.json: admission storm never preempted "
            "the prefill lane — the priority takeover path went unexercised"
        )
    if adm["ttft_p95_speedup"] < 2.0:
        sys.exit(
            "committed BENCH_serve.json: chunked interactive TTFT p95 only "
            f"{adm['ttft_p95_speedup']:.2f}x better than monolithic "
            "admission (floor 2.0x)"
        )
    if adm["itl_p95_vs_storm_free"] > 1.15:
        sys.exit(
            "committed BENCH_serve.json: chunked-storm decoder ITL p95 "
            f"{adm['itl_p95_vs_storm_free']:.2f}x the storm-free baseline "
            "(ceiling 1.15x)"
        )
    # PR 9: the ABFT/SDC phase must keep checksums near-free (abft-on ITL
    # p95 within 1.10x of abft-off — a timing gate read from the committed
    # JSON like the other ceilings) and exact: zero detections on clean
    # traffic, clean tokens bitwise identical to the unchecked engine, and
    # 100% detection/quarantine of the fired seeded faults
    if serve_f("sdc.overhead.abft_itl_p95_vs_off") > 1.10:
        sys.exit(
            "committed BENCH_serve.json: abft-on ITL p95 "
            f"{serve_f('sdc.overhead.abft_itl_p95_vs_off'):.2f}x the "
            "abft-off baseline (ceiling 1.10x)"
        )
    if serve_f("sdc.clean_false_positives") != 0:
        sys.exit(
            "committed BENCH_serve.json: abft flagged "
            f"{serve_f('sdc.clean_false_positives')} faults on clean "
            "traffic — the checksum tolerance has gone trigger-happy"
        )
    if not serve_f("sdc.bitwise_identical_to_off"):
        sys.exit(
            "committed BENCH_serve.json: abft-on tokens diverged from the "
            "unchecked engine — the checksum side-channel perturbed the "
            "product"
        )
    sdc_det = serve_f("sdc.detection")
    if sdc_det["injected_compute"] < 1 or sdc_det["injected_kv"] < 1:
        sys.exit(
            "committed BENCH_serve.json: the SDC phase fired no "
            f"{'compute' if sdc_det['injected_compute'] < 1 else 'KV'} "
            "faults — the detection rates prove nothing"
        )
    if sdc_det["detection_rate"] < 1.0 or sdc_det["kv_detection_rate"] < 1.0:
        sys.exit(
            "committed BENCH_serve.json: SDC detection below 100% "
            f"(compute {sdc_det['detection_rate']:.2f}, "
            f"kv {sdc_det['kv_detection_rate']:.2f})"
        )
    # PR 10: the DSE serve planner must keep its closed-loop claims — the
    # analytic model's top-1 config lands in the measured top-3 of a grid
    # of >= 8 real configs, and the full-space planner winner beats (or
    # ties) the shipped default's measured tokens/s.  Both are timing
    # claims from the machine that generated the JSON, gated here; the
    # fresh pass below re-checks the planner's exact invariants cheaply.
    if serve_f("autotune.grid_size") < 8:
        sys.exit(
            "committed BENCH_serve.json: autotune rank grid shrank below "
            f"8 configs ({serve_f('autotune.grid_size')})"
        )
    if not serve_f("autotune.rank_agreement_top1_in_top3"):
        sys.exit(
            "committed BENCH_serve.json: the cost model's top-1 serve "
            "config fell outside the measured top-3 — the planner's "
            "ranking no longer tracks the engine"
        )
    if serve_f("autotune.autotuned_vs_default_tokens_per_s") < 1.0:
        sys.exit(
            "committed BENCH_serve.json: autotuned config only "
            f"{serve_f('autotune.autotuned_vs_default_tokens_per_s'):.2f}x "
            "the shipped default (floor 1.0x)"
        )

    failures = []

    # PR 1: batched pricing rate (asserts batched == scalar internally)
    fresh_rate = perf_compare.bench_pricing_rate()
    if not _check(
        "mapper pricing",
        mapper_f("pricing.speedup"),
        fresh_rate["speedup"],
        args.tol,
    ):
        failures.append("mapper pricing")

    # PR 2: vectorized simulator (raises if it diverges from the odometer)
    with tempfile.TemporaryDirectory() as tmp:
        fresh_sim = perf_compare.run_simulate(os.path.join(tmp, "sim.json"), n=16)
    if not _check("simulate", sim_f("speedup"), fresh_sim["speedup"], args.tol):
        failures.append("simulate")

    # PR 3/4: continuous-vs-static serve throughput at equal slots, on a
    # reduced workload; the fresh run re-asserts batched-equals-solo
    # bitwise sampling internally
    fresh_serve = serve_bench.run(
        slots=serve_f("slots"),
        max_len=serve_f("max_len"),
        n_requests=8,
        repeats=2,
        out_path=None,
        scaling=False,
        ab=False,
        paged=False,
        fault_storm=False,
        crash_recovery=False,
        admission_storm=False,
        # the reduced-budget fresh_sdc pass below gates the SDC
        # invariants; the full phase re-runs the mid-size overhead A/B
        sdc=False,
        # the autotune rank grid measures ~12 engine configs; its timing
        # gates are committed-JSON claims, and the planner's exact
        # invariants are re-checked cheaply below without engine builds
        autotune=False,
    )
    if not fresh_serve["solo_outputs_identical"]:
        failures.append("serve solo-bitwise")
    if not _check(
        "serve continuous/static",
        serve_f("speedup_tokens_per_s"),
        fresh_serve["speedup_tokens_per_s"],
        args.serve_tol,
    ):
        failures.append("serve continuous/static")

    # PR 5: fresh paged-vs-contiguous differential on a reduced workload.
    # Both gates are exact, not timing: the agreement bit is bitwise token
    # equality, and the concurrency ratio is deterministic scheduling.
    import jax

    from repro.arch.model_zoo import build
    from repro.configs.registry import get

    cfg = get(serve_f("arch"))
    params = build(cfg).init(jax.random.PRNGKey(0))
    fresh_paged = serve_bench.bench_paged(
        cfg,
        params,
        slots=2,
        seed=0,
        n_requests=6,
        shared_max_len=160,
        shared_prefix=96,
        shared_requests=8,
    )
    ok_agree = (
        fresh_paged["agreement"]["bitwise_identical"]
        and fresh_paged["shared_prefix"]["bitwise_identical"]
    )
    ratio = fresh_paged["shared_prefix"]["admitted_concurrency_ratio"]
    print(
        f"[{'ok  ' if ok_agree else 'FAIL'}] paged bitwise agreement; "
        f"[{'ok  ' if ratio >= 1.5 else 'FAIL'}] shared-prefix "
        f"concurrency {ratio:.2f}x (floor 1.5x)"
    )
    if not ok_agree:
        failures.append("paged bitwise agreement")
    if ratio < 1.5:
        failures.append("paged shared-prefix concurrency")

    # PR 6: fresh fault storm on a reduced workload.  Only the exact
    # invariants are gated here (zero leaked blocks, survivors bitwise
    # equal to their unfaulted baseline, every preemption recovered) —
    # the ITL ceiling is a timing claim and is checked against the
    # committed JSON above, not a noisy shared CI runner.
    fresh_storm = serve_bench.bench_fault_storm(
        cfg, params, slots=2, seed=0, n_requests=10, hp_requests=2, repeats=1
    )
    storm_ok = (
        fresh_storm["leaked_blocks"] == 0
        and fresh_storm["bitwise_survivors_match_baseline"]
        and fresh_storm["recovered"] == fresh_storm["preemptions"]
    )
    print(
        f"[{'ok  ' if storm_ok else 'FAIL'}] fault storm: "
        f"leaked={fresh_storm['leaked_blocks']} "
        f"bitwise={fresh_storm['bitwise_survivors_match_baseline']} "
        f"preempted={fresh_storm['preemptions']} "
        f"recovered={fresh_storm['recovered']} "
        f"statuses={fresh_storm['statuses']}"
    )
    if not storm_ok:
        failures.append("fault-storm invariants")

    # PR 7: fresh kill/restore drill on a reduced workload.  Only the
    # exact invariants are gated (bitwise survivors, zero mismatches,
    # zero leaked blocks, at least one journaled token replayed) — the
    # ITL overhead ceiling is a timing claim and is checked against the
    # committed JSON above, not a noisy shared CI runner.
    fresh_cr = serve_bench.bench_crash_recovery(
        cfg, params, slots=2, seed=0, n_requests=6, repeats=1
    )
    rec = fresh_cr["recovery"]
    cr_ok = (
        rec["replay_mismatches"] == 0
        and rec["bitwise_survivors"]
        and rec["leaked_blocks"] == 0
        and rec["tokens_replayed"] >= 1
    )
    print(
        f"[{'ok  ' if cr_ok else 'FAIL'}] crash recovery: "
        f"source={rec['source']} replayed={rec['tokens_replayed']} "
        f"mismatches={rec['replay_mismatches']} "
        f"leaked={rec['leaked_blocks']} "
        f"readmit={rec['recovery_time_to_readmit_ms']:.0f}ms"
    )
    if not cr_ok:
        failures.append("crash-recovery invariants")

    # PR 8: fresh admission storm on a reduced schedule.  Only the exact
    # invariants are gated (chunked outputs bitwise equal to the
    # monolithic oracle, zero leaked blocks) — the TTFT/ITL gates are
    # timing claims checked against the committed JSON above, and lane
    # preemption needs full-scale wall-clock overlap (a toy bulk prefill
    # drains between arrivals), so it too is a committed-JSON gate.
    fresh_adm = serve_bench.bench_admission_storm(
        cfg,
        params,
        seed=0,
        slots=4,
        max_len=128,
        n_decoders=3,
        ramp_steps=12,
        n_bulk=2,
        bulk_prompt=40,
        bulk_new=3,
        inter_offsets=(0.0, 0.1),
        inter_new=4,
        prefill_chunk=8,
        window=60,
        mono_window=40,
        repeats=1,
    )
    adm_ok = (
        fresh_adm["bitwise_identical_to_monolithic"]
        and fresh_adm["leaked_blocks"] == 0
    )
    print(
        f"[{'ok  ' if adm_ok else 'FAIL'}] admission storm: "
        f"bitwise={fresh_adm['bitwise_identical_to_monolithic']} "
        f"leaked={fresh_adm['leaked_blocks']} "
        f"lane_preemptions={fresh_adm['lane_preemptions']}"
    )
    if not adm_ok:
        failures.append("admission-storm invariants")

    # PR 9: fresh ABFT/SDC pass on a reduced budget.  Only the exact
    # invariants are gated (100% detection of fired faults, zero clean
    # false positives, clean tokens bitwise equal to the unchecked
    # engine) — the ITL overhead ceiling is a timing claim checked
    # against the committed JSON above.  Every episode also re-asserts
    # the full detect->localize->retry->quarantine ledger internally.
    fresh_sdc = serve_bench.bench_sdc(
        cfg, params, slots=2, seed=0, n_requests=6, repeats=1, episodes=2
    )
    det = fresh_sdc["detection"]
    sdc_ok = (
        fresh_sdc["clean_false_positives"] == 0
        and fresh_sdc["bitwise_identical_to_off"]
        and det["detection_rate"] >= 1.0
        and det["kv_detection_rate"] >= 1.0
        and det["injected_compute"] + det["injected_kv"] >= 1
    )
    print(
        f"[{'ok  ' if sdc_ok else 'FAIL'}] sdc/abft: "
        f"detected={det['detected']}/{det['injected_compute']} "
        f"quarantined={det['quarantined']}/{det['injected_kv']} "
        f"clean_fps={fresh_sdc['clean_false_positives']} "
        f"bitwise={fresh_sdc['bitwise_identical_to_off']}"
    )
    if not sdc_ok:
        failures.append("sdc/abft invariants")

    # PR 10: fresh planner invariants, no engine builds (the measured rank
    # and A/B gates are timing claims checked against the committed JSON
    # above): planning must be deterministic, the winner must survive a
    # cache round-trip, and a corrupted cache entry must be re-searched
    # rather than served.
    from repro.core import serveplan

    with tempfile.TemporaryDirectory() as tmp:
        plan_path = os.path.join(tmp, "plans.json")
        p1 = serveplan.plan_serve(cfg, max_len=64, cache=plan_path)
        p2 = serveplan.plan_serve(cfg, max_len=64, cache=plan_path)
        with open(plan_path) as f:
            store = json.load(f)
        (plan_key,) = store.keys()
        store[plan_key]["knobs"]["block_size"] = -1
        with open(plan_path, "w") as f:
            json.dump(store, f)
        p3 = serveplan.plan_serve(cfg, max_len=64, cache=plan_path)
    plan_ok = (
        p1.source == "search"
        and p2.source == "cache"
        and p3.source == "search"
        and p1.knobs == p2.knobs == p3.knobs
    )
    print(
        f"[{'ok  ' if plan_ok else 'FAIL'}] serve planner: "
        f"deterministic={p1.knobs == p3.knobs} "
        f"cache_hit={p2.source == 'cache'} "
        f"corrupt_entry_replanned={p3.source == 'search'} "
        f"winner={p1.knobs.kv_layout}/slots={p1.knobs.slots}"
    )
    if not plan_ok:
        failures.append("serve planner invariants")

    if args.full:
        fresh_sweep = perf_compare.bench_network_sweep()
        if not fresh_sweep["identical_best"]:
            failures.append("sweep identical_best")
        if not _check(
            "optimize_network sweep",
            mapper["optimize_network"]["speedup"],
            fresh_sweep["speedup"],
            args.tol,
        ):
            failures.append("optimize_network sweep")

    if failures:
        sys.exit(f"benchmark regression: {', '.join(failures)}")
    print("bench-check: committed speedups hold")


if __name__ == "__main__":
    main()
