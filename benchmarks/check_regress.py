"""Guard the committed BENCH_*.json speedups against silent regression.

Re-measures the PR-1 batched-pricing engine, the PR-2 vectorized
simulator, the PR-3/4 serve engine (continuous-vs-static batching at
equal slots, solo-bitwise outputs), the PR-5 paged KV layout
(bitwise agreement with the contiguous oracle + the iso-memory
shared-prefix concurrency win), and the PR-6 request-lifecycle fault
storm (zero leaked blocks, bitwise-stable survivors, preemptions all
recovered, survivor ITL p95 within 1.25x of the no-fault baseline)
on reduced budgets and compares against
the committed BENCH_mapper.json / BENCH_simulate.json / BENCH_serve.json
claims:

    PYTHONPATH=src python -m benchmarks.check_regress [--full] [--tol 0.15]

The tolerance is deliberately generous (default: fresh speedup must reach
15% of the committed one; the serve ratio, being O(1.3-2x), uses its own
``--serve-tol`` floor fraction) because CI runners are noisy and shared —
the guard exists to catch the engine quietly falling back to a scalar path
or losing an order of magnitude, not 2x jitter.  ``--full`` additionally
re-runs the end-to-end optimize_network sweep (minutes).  The fresh runs
re-assert correctness against their oracles (bit-identity for the
simulator/pricer, batched-equals-solo bitwise sampling for serving), so
correctness rot fails the guard too.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _load(path: str) -> dict:
    if not os.path.exists(path):
        sys.exit(f"missing committed benchmark file: {path}")
    with open(path) as f:
        return json.load(f)


def _check(name: str, committed: float, fresh: float, tol: float) -> bool:
    floor = committed * tol
    ok = fresh >= floor
    status = "ok  " if ok else "FAIL"
    print(
        f"[{status}] {name}: committed {committed:8.1f}x   "
        f"fresh {fresh:8.1f}x   floor {floor:6.1f}x"
    )
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tol",
        type=float,
        default=0.15,
        help="fresh speedup must reach this fraction of the committed one",
    )
    ap.add_argument(
        "--full",
        action="store_true",
        help="also re-run the end-to-end optimize_network sweep (minutes)",
    )
    ap.add_argument(
        "--serve-tol",
        type=float,
        default=0.5,
        help="fresh continuous-vs-static ratio must reach this fraction "
        "of the committed one (serve ratios are O(1.3-2x), so the "
        "generic --tol would never trip)",
    )
    ap.add_argument("--mapper-json", default="BENCH_mapper.json")
    ap.add_argument("--simulate-json", default="BENCH_simulate.json")
    ap.add_argument("--serve-json", default="BENCH_serve.json")
    args = ap.parse_args()

    from benchmarks import perf_compare, serve_bench

    mapper = _load(args.mapper_json)
    simulate = _load(args.simulate_json)
    serve = _load(args.serve_json)
    if not simulate.get("bit_identical", False):
        sys.exit("committed BENCH_simulate.json lost bit_identical=true")
    if not mapper["optimize_network"].get("identical_best", False):
        sys.exit("committed BENCH_mapper.json lost identical_best=true")
    if not serve.get("solo_outputs_identical", False):
        sys.exit("committed BENCH_serve.json lost solo_outputs_identical=true")
    if serve["attention_ab"]["flash_vs_oracle_speedup"] < 1.0:
        sys.exit(
            "committed BENCH_serve.json: flash-decoding slower than the "
            "masked-oracle attend path"
        )
    # PR 5: the paged KV layout must stay bitwise-agreeing with the
    # contiguous oracle, and the shared-prefix workload must keep its
    # iso-memory concurrency win (this ratio is deterministic scheduling,
    # not timing, so no noise tolerance applies)
    if not serve["paged"]["agreement"]["bitwise_identical"]:
        sys.exit("committed BENCH_serve.json: paged != contiguous bitwise")
    if not serve["paged"]["shared_prefix"]["bitwise_identical"]:
        sys.exit(
            "committed BENCH_serve.json: shared-prefix paged outputs "
            "diverged from the contiguous oracle"
        )
    if serve["paged"]["shared_prefix"]["admitted_concurrency_ratio"] < 1.5:
        sys.exit(
            "committed BENCH_serve.json: shared-prefix paged concurrency "
            "win below the 1.5x floor"
        )
    # PR 6: the fault-storm phase must show a leak-free, bitwise-stable
    # engine under cancellation/deadline/preemption fire, and survivors
    # must not be badly degraded (ITL p95 within 1.25x of the no-fault
    # baseline — the one timing gate here, measured as a median of paired
    # back-to-back runs to shed scheduler noise)
    storm = serve["fault_storm"]
    if storm["leaked_blocks"] != 0:
        sys.exit(
            "committed BENCH_serve.json: fault storm leaked "
            f"{storm['leaked_blocks']} KV blocks"
        )
    if not storm["bitwise_survivors_match_baseline"]:
        sys.exit(
            "committed BENCH_serve.json: fault-storm survivors diverged "
            "from their unfaulted baseline outputs"
        )
    if storm["survivor_itl_p95_vs_baseline"] > 1.25:
        sys.exit(
            "committed BENCH_serve.json: fault-storm survivor ITL p95 "
            f"{storm['survivor_itl_p95_vs_baseline']:.2f}x the no-fault "
            "baseline (ceiling 1.25x)"
        )
    if storm["preemptions"] < 1 or storm["recovered"] < storm["preemptions"]:
        sys.exit(
            "committed BENCH_serve.json: fault storm must exercise "
            "preemption and recover every victim "
            f"(preemptions={storm['preemptions']}, "
            f"recovered={storm['recovered']})"
        )

    failures = []

    # PR 1: batched pricing rate (asserts batched == scalar internally)
    fresh_rate = perf_compare.bench_pricing_rate()
    if not _check(
        "mapper pricing",
        mapper["pricing"]["speedup"],
        fresh_rate["speedup"],
        args.tol,
    ):
        failures.append("mapper pricing")

    # PR 2: vectorized simulator (raises if it diverges from the odometer)
    with tempfile.TemporaryDirectory() as tmp:
        fresh_sim = perf_compare.run_simulate(os.path.join(tmp, "sim.json"), n=16)
    if not _check("simulate", simulate["speedup"], fresh_sim["speedup"], args.tol):
        failures.append("simulate")

    # PR 3/4: continuous-vs-static serve throughput at equal slots, on a
    # reduced workload; the fresh run re-asserts batched-equals-solo
    # bitwise sampling internally
    fresh_serve = serve_bench.run(
        slots=serve["slots"],
        max_len=serve["max_len"],
        n_requests=8,
        repeats=2,
        out_path=None,
        scaling=False,
        ab=False,
        paged=False,
        fault_storm=False,
    )
    if not fresh_serve["solo_outputs_identical"]:
        failures.append("serve solo-bitwise")
    if not _check(
        "serve continuous/static",
        serve["speedup_tokens_per_s"],
        fresh_serve["speedup_tokens_per_s"],
        args.serve_tol,
    ):
        failures.append("serve continuous/static")

    # PR 5: fresh paged-vs-contiguous differential on a reduced workload.
    # Both gates are exact, not timing: the agreement bit is bitwise token
    # equality, and the concurrency ratio is deterministic scheduling.
    import jax

    from repro.arch.model_zoo import build
    from repro.configs.registry import get

    cfg = get(serve["arch"])
    params = build(cfg).init(jax.random.PRNGKey(0))
    fresh_paged = serve_bench.bench_paged(
        cfg,
        params,
        slots=2,
        seed=0,
        n_requests=6,
        shared_max_len=160,
        shared_prefix=96,
        shared_requests=8,
    )
    ok_agree = (
        fresh_paged["agreement"]["bitwise_identical"]
        and fresh_paged["shared_prefix"]["bitwise_identical"]
    )
    ratio = fresh_paged["shared_prefix"]["admitted_concurrency_ratio"]
    print(
        f"[{'ok  ' if ok_agree else 'FAIL'}] paged bitwise agreement; "
        f"[{'ok  ' if ratio >= 1.5 else 'FAIL'}] shared-prefix "
        f"concurrency {ratio:.2f}x (floor 1.5x)"
    )
    if not ok_agree:
        failures.append("paged bitwise agreement")
    if ratio < 1.5:
        failures.append("paged shared-prefix concurrency")

    # PR 6: fresh fault storm on a reduced workload.  Only the exact
    # invariants are gated here (zero leaked blocks, survivors bitwise
    # equal to their unfaulted baseline, every preemption recovered) —
    # the ITL ceiling is a timing claim and is checked against the
    # committed JSON above, not a noisy shared CI runner.
    fresh_storm = serve_bench.bench_fault_storm(
        cfg, params, slots=2, seed=0, n_requests=10, hp_requests=2, repeats=1
    )
    storm_ok = (
        fresh_storm["leaked_blocks"] == 0
        and fresh_storm["bitwise_survivors_match_baseline"]
        and fresh_storm["recovered"] == fresh_storm["preemptions"]
    )
    print(
        f"[{'ok  ' if storm_ok else 'FAIL'}] fault storm: "
        f"leaked={fresh_storm['leaked_blocks']} "
        f"bitwise={fresh_storm['bitwise_survivors_match_baseline']} "
        f"preempted={fresh_storm['preemptions']} "
        f"recovered={fresh_storm['recovered']} "
        f"statuses={fresh_storm['statuses']}"
    )
    if not storm_ok:
        failures.append("fault-storm invariants")

    if args.full:
        fresh_sweep = perf_compare.bench_network_sweep()
        if not fresh_sweep["identical_best"]:
            failures.append("sweep identical_best")
        if not _check(
            "optimize_network sweep",
            mapper["optimize_network"]["speedup"],
            fresh_sweep["speedup"],
            args.tol,
        ):
            failures.append("optimize_network sweep")

    if failures:
        sys.exit(f"benchmark regression: {', '.join(failures)}")
    print("bench-check: committed speedups hold")


if __name__ == "__main__":
    main()
