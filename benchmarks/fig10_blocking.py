"""Fig 10 analogue: the loop-blocking design space is WIDE.

Paper claim: for AlexNet CONV3 with C|K on the Eyeriss-like config, blocking
variance dwarfs dataflow variance; only ~30% of blocking schemes land within
1.25x of the minimum energy.
"""

from __future__ import annotations

import itertools

from repro.core import ArraySpec, evaluate, make_dataflow
from repro.core.blocking import iter_blockings, search_blocking
from repro.core.networks import alexnet_conv3
from repro.core.schedule import MemLevel

LEVELS = (
    MemLevel("RF", 512, double_buffered=False, per_pe=True),
    MemLevel("BUF", 128 * 1024),
    MemLevel("DRAM", None),
)


def run(n_samples: int = 1500, beam: int = 24):
    nest = alexnet_conv3()
    arr = ArraySpec(dims=(16, 16))
    df = make_dataflow(nest, arr, ("C", "K"))
    energies = []
    for s in itertools.islice(
        iter_blockings(nest, LEVELS, arr, df, max_choices_per_level=16),
        n_samples,
    ):
        energies.append(evaluate(s).energy_pj)
    best_search = search_blocking(nest, LEVELS, arr, df, beam=beam).best
    mn = min(min(energies), best_search.energy_pj)
    frac_125 = sum(1 for e in energies if e <= 1.25 * mn) / len(energies)
    frac_2x = sum(1 for e in energies if e <= 2 * mn) / len(energies)
    spread = max(energies) / mn
    return dict(
        n=len(energies), min_uj=mn / 1e6, frac_within_125=frac_125,
        frac_within_2x=frac_2x, spread=spread,
        search_uj=best_search.energy_pj / 1e6,
    )


def main():
    r = run()
    print(
        f"fig10,blocking_space,n={r['n']},min={r['min_uj']:.0f}uJ,"
        f"within1.25x={r['frac_within_125']:.2f},"
        f"within2x={r['frac_within_2x']:.2f},spread={r['spread']:.1f}x,"
        f"beam_search={r['search_uj']:.0f}uJ"
    )


if __name__ == "__main__":
    main()
