"""Fig 9 analogue: PE-array utilization with and without replication.

Paper claims: (a) without replication utilization varies wildly across
dataflows and is often low; (b) replication lifts nearly all dataflows to
high utilization; (c) C|K achieves ~20% higher utilization than FY|Y-style
flows on CONV3 since channel dims are largest.
"""

from __future__ import annotations

from repro.core import ArraySpec, enumerate_dataflows
from repro.core.networks import alexnet_conv3, googlenet_4c3r
from repro.core.schedule import flat_schedule, MemLevel

LEVELS = (
    MemLevel("RF", 512, double_buffered=False, per_pe=True),
    MemLevel("BUF", 128 * 1024),
    MemLevel("DRAM", None),
)


def utilizations(nest, replication: bool):
    arr = ArraySpec(dims=(16, 16))
    out = {}
    for df in enumerate_dataflows(nest, arr, replication=replication):
        s = flat_schedule(nest, LEVELS, array=arr, spatial=df.assigns)
        out[df.label()] = s.utilization()
    return out


def main():
    for name, nest in (
        ("alexnet_conv3", alexnet_conv3()),
        ("googlenet_4c3r", googlenet_4c3r()),
    ):
        for repl in (False, True):
            u = utilizations(nest, repl)
            vals = sorted(u.values())
            ck = next(
                (v for k, v in u.items() if k.startswith("CK|") or "C|K" in k
                 or k.startswith("C") and "|K" in k),
                None,
            )
            print(
                f"fig9,{name},replication={repl},"
                f"min={vals[0]:.2f},median={vals[len(vals)//2]:.2f},"
                f"max={vals[-1]:.2f}"
                + (f",C|K={ck:.2f}" if ck is not None else "")
            )


if __name__ == "__main__":
    main()
