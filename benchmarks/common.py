"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import time
from contextlib import contextmanager

from repro.core.energy import CostTable
from repro.core.loopnest import LoopNest
from repro.core.optimizer import HardwareConfig, LayerResult, optimize_layer

# cache layer results across hw configs / figures (keyed by bounds + hw);
# optimize_layer additionally memoizes the underlying blocking searches
# structurally, so repeated layer shapes are solved once per hierarchy.
_LAYER_CACHE: dict = {}

# cost tables depend only on the hierarchy: build once per hw config
_TABLE_CACHE: dict = {}


def cached_optimize_layer(
    nest: LoopNest, hw: HardwareConfig, beam: int = 16
) -> LayerResult:
    key = (
        tuple(sorted(nest.bounds.items())),
        tuple(t.name for t in nest.tensors),
        hw.name, hw.array.dims, hw.rf_bytes, hw.buffer_bytes, beam,
    )
    if key in _LAYER_CACHE:
        return _LAYER_CACHE[key]
    hw_key = (hw.array.dims, hw.rf_bytes, hw.buffer_bytes)
    if hw_key not in _TABLE_CACHE:
        _TABLE_CACHE[hw_key] = CostTable.for_levels(hw.levels())
    out = optimize_layer(
        nest, hw, max_evals=0, table=_TABLE_CACHE[hw_key], beam=beam
    )
    _LAYER_CACHE[key] = out
    return out


def network_energy(layers, hw: HardwareConfig, beam: int = 16) -> float:
    return sum(
        cached_optimize_layer(n, hw, beam).report.energy_pj for n in layers
    )


@contextmanager
def timed(results: list, name: str, derived: str = ""):
    t0 = time.perf_counter()
    holder = {}
    yield holder
    us = (time.perf_counter() - t0) * 1e6
    results.append((name, us, holder.get("derived", derived)))


def print_csv(results):
    for name, us, derived in results:
        print(f"{name},{us:.0f},{derived}")
