"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import time
from contextlib import contextmanager

from repro.core import (
    ArraySpec,
    MemLevel,
    search_blocking,
)
from repro.core.loopnest import LoopNest
from repro.core.optimizer import HardwareConfig, LayerResult, ck_dataflow

# cache layer results across hw configs / figures (keyed by bounds + hw)
_LAYER_CACHE: dict = {}


def cached_optimize_layer(
    nest: LoopNest, hw: HardwareConfig, beam: int = 16
) -> LayerResult:
    key = (
        tuple(sorted(nest.bounds.items())),
        tuple(t.name for t in nest.tensors),
        hw.name, hw.array.dims, hw.rf_bytes, hw.buffer_bytes, beam,
    )
    if key in _LAYER_CACHE:
        return _LAYER_CACHE[key]
    df = ck_dataflow(nest, hw.array)
    res = search_blocking(nest, hw.levels(), hw.array, df, beam=beam)
    out = LayerResult(nest=nest, report=res.best, dataflow=df)
    _LAYER_CACHE[key] = out
    return out


def network_energy(layers, hw: HardwareConfig, beam: int = 16) -> float:
    return sum(
        cached_optimize_layer(n, hw, beam).report.energy_pj for n in layers
    )


@contextmanager
def timed(results: list, name: str, derived: str = ""):
    t0 = time.perf_counter()
    holder = {}
    yield holder
    us = (time.perf_counter() - t0) * 1e6
    results.append((name, us, holder.get("derived", derived)))


def print_csv(results):
    for name, us, derived in results:
        print(f"{name},{us:.0f},{derived}")
