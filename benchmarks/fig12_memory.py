"""Fig 11/12 analogue: memory resource allocation dominates energy.

Paper claims: (a) with a 512 B RF the RF level dominates AlexNet energy;
(b) shrinking the RF to 32-64 B improves total energy up to ~2.6x;
(c) growing the SRAM buffer beyond 256 KB gives negligible returns;
(d) a two-level RF (16 B + 256 B) + 256 KB buffer adds ~25%.
"""

from __future__ import annotations

from benchmarks.common import network_energy
from repro.core import ArraySpec
from repro.core.networks import alexnet
from repro.core.optimizer import HardwareConfig

ARR = ArraySpec(dims=(16, 16))


def rf_sweep(beam: int = 12):
    layers = alexnet()
    rows = []
    for rf in (32, 64, 128, 256, 512):
        for buf_k in (64, 128, 256, 512):
            hw = HardwareConfig(
                f"rf{rf}-buf{buf_k}k", ARR, (rf,), (buf_k * 1024,)
            )
            rows.append((rf, buf_k, network_energy(layers, hw, beam)))
    return rows


def two_level_rf(beam: int = 12):
    layers = alexnet()
    one = HardwareConfig("rf64", ARR, (64,), (256 * 1024,))
    two = HardwareConfig("rf16+256", ARR, (16, 256), (256 * 1024,))
    return (
        network_energy(layers, one, beam),
        network_energy(layers, two, beam),
    )


def main():
    rows = rf_sweep()
    base = next(e for rf, bk, e in rows if rf == 512 and bk == 128)
    best = min(rows, key=lambda r: r[2])
    for rf, buf_k, e in rows:
        print(f"fig12,rf={rf}B,buf={buf_k}KB,energy={e/1e6:.0f}uJ,"
              f"vs_eyeriss512={base/e:.2f}x")
    print(
        f"fig12,summary,best=rf{best[0]}-buf{best[1]}k,"
        f"improvement={base/best[2]:.2f}x"
    )
    e1, e2 = two_level_rf()
    print(f"fig12,two_level_rf,one={e1/1e6:.0f}uJ,two={e2/1e6:.0f}uJ,"
          f"gain={e1/e2:.2f}x")


if __name__ == "__main__":
    main()
