"""Benchmark driver: one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines.  Default mode runs reduced
budgets suitable for CI; ``--full`` reproduces the paper-scale sweeps
(hours on one CPU core).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,fig14]
"""

from __future__ import annotations

import argparse
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def run_validation(full: bool):
    from benchmarks import validation

    _, us = _timed(validation.main)
    print(f"validation_total,{us:.0f},model==simulator")


def run_fig8(full: bool):
    from benchmarks import fig8_dataflow

    layers = ("conv3", "4c3r") if full else ("conv3",)
    for layer in layers:
        rows, us = _timed(fig8_dataflow.run, layer, 16, 12 if full else 6)
        for row in rows:
            print(
                f"fig8_{layer}_{row['hw']},{us/len(rows):.0f},"
                f"median/best={row['median_over_best']:.2f};"
                f"within2x={row['frac_within_2x']:.2f};n={row['n_dataflows']}"
            )


def run_fig9(full: bool):
    from benchmarks import fig9_utilization

    _, us = _timed(fig9_utilization.main)
    print(f"fig9_total,{us:.0f},replication_restores_utilization")


def run_fig10(full: bool):
    from benchmarks import fig10_blocking

    r, us = _timed(fig10_blocking.run, 1500 if full else 400)
    print(
        f"fig10,{us:.0f},within1.25x={r['frac_within_125']:.2f};"
        f"spread={r['spread']:.1f}x;min={r['min_uj']:.0f}uJ"
    )


def run_fig12(full: bool):
    from benchmarks import fig12_memory

    rows, us = _timed(fig12_memory.rf_sweep, 12 if full else 8)
    base = next(e for rf, bk, e in rows if rf == 512 and bk == 128)
    best = min(rows, key=lambda r: r[2])
    print(
        f"fig12,{us:.0f},best=rf{best[0]}B+buf{best[1]}KB;"
        f"gain_vs_eyeriss={base/best[2]:.2f}x"
    )
    (e1, e2), us2 = _timed(fig12_memory.two_level_rf, 12 if full else 8)
    print(f"fig12_two_level_rf,{us2:.0f},gain={e1/e2:.2f}x")


def run_fig13(full: bool):
    from benchmarks import fig13_scaling

    rows, us = _timed(fig13_scaling.run, 10 if full else 6)
    derived = ";".join(
        f"pe{n}:rf{b[1]}B+buf{b[2]//1024}KB" for n, b in rows
    )
    print(f"fig13,{us:.0f},{derived}")


def run_fig14(full: bool):
    from benchmarks import fig14_optimizer
    from repro.core.networks import PAPER_BENCHMARKS

    names = list(PAPER_BENCHMARKS) if full else ["alexnet", "lstm_m", "mlp_m"]
    _, us = _timed(fig14_optimizer.main, 10 if full else 6, names)
    print(f"fig14_total,{us:.0f},optimizer_gains_above")


def run_roofline(full: bool):
    from benchmarks import roofline

    rows, us = _timed(roofline.load_all)
    if not rows:
        print("roofline,0,no_dryrun_records(run launch/dryrun first)")
        return
    import os

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_baseline.md", "w") as f:
        f.write(roofline.markdown_table(rows))
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    n_cb = sum(1 for r in rows if r["dominant"] == "compute")
    print(
        f"roofline,{us:.0f},cells={len(rows)};compute_bound={n_cb};"
        f"worst={worst['arch']}/{worst['shape']}@{worst['roofline_fraction']:.2f}"
    )


def run_kernels(full: bool):
    """Micro-bench the Pallas kernels (interpret mode wall time is NOT TPU
    perf - recorded for regression tracking only)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.matmul.ops import matmul
    from repro.kernels.matmul.ref import matmul_ref

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(key, (256, 256), jnp.float32)
    _, us = _timed(lambda: jax.block_until_ready(matmul(a, b)))
    _, us_ref = _timed(lambda: jax.block_until_ready(matmul_ref(a, b)))
    print(f"kernel_matmul_256_interp,{us:.0f},ref_us={us_ref:.0f}")


SECTIONS = {
    "validation": run_validation,
    "fig8": run_fig8,
    "fig9": run_fig9,
    "fig10": run_fig10,
    "fig12": run_fig12,
    "fig13": run_fig13,
    "fig14": run_fig14,
    "roofline": run_roofline,
    "kernels": run_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    failed = []
    for name, fn in SECTIONS.items():
        if only and name not in only:
            continue
        try:
            fn(args.full)
        except Exception as e:  # keep the suite running, fail at the end
            failed.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
    if failed:
        raise SystemExit(f"benchmark sections failed: {','.join(failed)}")


if __name__ == "__main__":
    main()
