"""Fig 10-12 analogue: iso-throughput memory-resource-allocation sweep.

Reproduces the paper's headline §6.3 experiment end-to-end on the DSE suite
(CNN + LSTM + MLP, core/networks.py): sweep every Obs-2 candidate memory
hierarchy (one- and two-level register files x buffer sizes) on a fixed
16x16 PE array, and report how much energy the best allocation saves over
an Eyeriss-like baseline allocation at constant throughput (the paper
measures up to 4.2x for CNNs, 1.6x for LSTMs, 1.8x for MLPs on the full
benchmark suite).

Two engines are timed on identical hierarchy grids:

  * sequential — the existing `optimize_network` loop: one full blocking
    search per (hierarchy x layer),
  * batched    — `dse.sweep_allocations`: one shared frontier + counts pass
    per (layer-shape x hierarchy-family), priced under every member's cost
    table in a single 4-D call.

Emits BENCH_dse.json.

    PYTHONPATH=src python -m benchmarks.fig_dse [--out BENCH_dse.json]
        [--workers N] [--cache PATH] [--skip-sequential]
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core.dse import (
    best_at_iso_throughput,
    pareto_prune,
    sweep_allocations,
)
from repro.core.networks import DSE_SUITE
from repro.core.optimizer import (
    HardwareConfig,
    candidate_hierarchies,
    clear_search_cache,
    optimize_network,
)
from repro.core.schedule import ArraySpec

ARRAY = ArraySpec(dims=(16, 16))


def baseline_hw() -> HardwareConfig:
    """Eyeriss-like allocation on the sweep's array: 512 B RF, 128 KB buffer
    (outside the Obs-2 ratio band — that imbalance is the point)."""
    return HardwareConfig(
        name="baseline-rf512-buf128k",
        array=ARRAY,
        rf_bytes=(512,),
        buffer_bytes=(128 * 1024,),
    )


def run_network(
    name: str,
    layers,
    hws,
    *,
    workers: int = 0,
    cache=None,
    skip_sequential: bool = False,
) -> dict:
    base = baseline_hw()
    grid = list(hws) + [base]

    t0 = time.perf_counter()
    points = sweep_allocations(
        layers, ARRAY, grid, workers=workers, cache=cache
    )
    t_batched = time.perf_counter() - t0

    by_name = {p.hw.name: p for p in points}
    base_pt = by_name.get(base.name)
    if base_pt is None:
        # sweep_allocations drops hierarchies with no feasible schedule
        raise ValueError(
            f"baseline hierarchy {base.name} is infeasible for network "
            f"{name!r}; every ratio in this record depends on it"
        )
    best = min(points, key=lambda p: p.energy_pj)
    try:
        best_iso = best_at_iso_throughput(points, base_pt, slack=1.0)
    except ValueError:
        best_iso = base_pt
    frontier = pareto_prune(points)

    rec = {
        "network": name,
        "layers": len(layers),
        "hierarchies": len(grid),
        "batched_s": t_batched,
        "design_points": len(points),
        "baseline": {
            "hw": base.name,
            "energy_pj": base_pt.energy_pj,
            "cycles": base_pt.cycles,
        },
        "best": {
            "hw": best.hw.name,
            "energy_pj": best.energy_pj,
            "cycles": best.cycles,
        },
        "best_iso_throughput": {
            "hw": best_iso.hw.name,
            "energy_pj": best_iso.energy_pj,
            "cycles": best_iso.cycles,
        },
        "energy_improvement": base_pt.energy_pj / best.energy_pj,
        "energy_improvement_iso": base_pt.energy_pj / best_iso.energy_pj,
        # Fig-12-style spread: how much the allocation choice matters at all
        "energy_spread": max(p.energy_pj for p in points) / best.energy_pj,
        "pareto": [
            {"hw": p.hw.name, "energy_pj": p.energy_pj, "cycles": p.cycles}
            for p in sorted(frontier, key=lambda p: p.energy_pj)
        ],
    }

    if not skip_sequential:
        clear_search_cache()
        t0 = time.perf_counter()
        seq = optimize_network(layers, ARRAY, hw_candidates=grid)
        t_seq = time.perf_counter() - t0
        rec["sequential_s"] = t_seq
        rec["speedup"] = t_seq / t_batched
        rec["sequential_best"] = {
            "hw": seq.hw.name,
            "energy_pj": seq.total_energy_pj,
        }
        rec["best_hw_agrees"] = seq.hw.name == best.hw.name
        rec["best_energy_gap"] = best.energy_pj / seq.total_energy_pj - 1.0
    return rec


def run(
    out_path: str,
    workers: int = 0,
    cache=None,
    skip_sequential: bool = False,
) -> dict:
    hws = candidate_hierarchies(ARRAY, two_level_rf=True)
    nets = {}
    for name, maker in DSE_SUITE.items():
        nets[name] = run_network(
            name, maker(), hws,
            workers=workers, cache=cache, skip_sequential=skip_sequential,
        )
        r = nets[name]
        line = (
            f"{name}: {r['hierarchies']} hierarchies, batched "
            f"{r['batched_s']:.2f}s, improvement {r['energy_improvement']:.2f}x"
            f" (iso {r['energy_improvement_iso']:.2f}x)"
        )
        if "speedup" in r:
            line += (
                f", sequential {r['sequential_s']:.2f}s "
                f"-> speedup {r['speedup']:.1f}x "
                f"(agree={r['best_hw_agrees']}, "
                f"gap={r['best_energy_gap']*100:.2f}%)"
            )
        print(line)

    result = {"array": list(ARRAY.dims), "networks": nets}
    if not skip_sequential:
        tb = sum(r["batched_s"] for r in nets.values())
        ts = sum(r["sequential_s"] for r in nets.values())
        result["total_batched_s"] = tb
        result["total_sequential_s"] = ts
        result["total_speedup"] = ts / tb
        print(f"total: batched {tb:.2f}s, sequential {ts:.2f}s, "
              f"speedup {ts/tb:.1f}x")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dse.json")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--cache", default=None,
                    help="JSON cache path for incremental re-runs")
    ap.add_argument("--skip-sequential", action="store_true",
                    help="only run the batched sweep (no baseline timing)")
    args = ap.parse_args()
    run(args.out, workers=args.workers, cache=args.cache,
        skip_sequential=args.skip_sequential)


if __name__ == "__main__":
    main()
