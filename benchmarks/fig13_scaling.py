"""Fig 13 analogue: optimal memory allocation vs PE-array size.

Paper claims: as the PE count grows, the optimal per-level memory size grows
SUB-linearly (access energy grows with size), and total energy decreases
slightly (more on-chip reuse, mostly nearest-neighbor traffic).
"""

from __future__ import annotations

from benchmarks.common import network_energy
from repro.core import ArraySpec
from repro.core.networks import alexnet
from repro.core.optimizer import HardwareConfig, RF_CHOICES, BUF_CHOICES


def run(beam: int = 10):
    layers = alexnet()
    rows = []
    for dim in (8, 16, 32):
        arr = ArraySpec(dims=(dim, dim))
        best = None
        for rf in RF_CHOICES:
            for buf in BUF_CHOICES:
                hw = HardwareConfig(
                    f"pe{dim}-rf{rf}-buf{buf//1024}k", arr, (rf,), (buf,)
                )
                try:
                    e = network_energy(layers, hw, beam)
                except ValueError:
                    continue
                if best is None or e < best[0]:
                    best = (e, rf, buf)
        rows.append((dim * dim, best))
    return rows


def main():
    rows = run()
    for n_pe, (e, rf, buf) in rows:
        print(
            f"fig13,pes={n_pe},opt_rf={rf}B,opt_buf={buf//1024}KB,"
            f"energy={e/1e6:.0f}uJ,total_rf={n_pe*rf//1024}KB"
        )


if __name__ == "__main__":
    main()
