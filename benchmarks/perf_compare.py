"""§Perf helper: compare variant dry-run records against the baseline.

    PYTHONPATH=src python -m benchmarks.perf_compare \
        --cell grok-1-314b train_4k 16x16 [--tag dots]

Prints the three roofline terms before/after plus deltas - the measurement
step of the hypothesis -> change -> measure loop.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import analyze_record


def load(path: str) -> dict:
    with open(path) as f:
        return analyze_record(json.load(f), path)


def compare(base: dict, var: dict) -> str:
    lines = [
        f"cell: {base['arch']} x {base['shape']} x {base['mesh']}",
        f"{'term':<14}{'baseline':>12}{'variant':>12}{'delta':>9}",
    ]
    for term in ("compute_s", "memory_s", "collective_s"):
        b, v = base[term], var[term]
        d = (v - b) / b * 100 if b else float("nan")
        lines.append(f"{term:<14}{b:>12.3e}{v:>12.3e}{d:>8.1f}%")
    lines.append(
        f"{'rf':<14}{base['roofline_fraction']:>12.3f}"
        f"{var['roofline_fraction']:>12.3f}"
    )
    lines.append(
        f"{'useful':<14}{base['useful_ratio']:>12.3f}"
        f"{var['useful_ratio']:>12.3f}"
    )
    lines.append(
        f"{'peakGiB':<14}{base['peak_gib']:>12.2f}{var['peak_gib']:>12.2f}"
    )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=3, metavar=("ARCH", "SHAPE", "MESH"),
                    required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    arch, shape, mesh = args.cell
    base = load(os.path.join(args.dir, f"{arch}__{shape}__{mesh}.json"))
    var = load(
        os.path.join(args.dir, f"{arch}__{shape}__{mesh}__{args.tag}.json")
    )
    print(compare(base, var))


if __name__ == "__main__":
    main()
