"""§Perf helper: compare variant dry-run records against the baseline.

    PYTHONPATH=src python -m benchmarks.perf_compare \
        --cell grok-1-314b train_4k 16x16 [--tag dots]

Prints the three roofline terms before/after plus deltas - the measurement
step of the hypothesis -> change -> measure loop.

Mapper mode benchmarks the batched cost-model engine against the scalar
oracle (mappings priced per second, plus an end-to-end optimize_network
hardware sweep with seed-equivalent scalar search as the baseline) and
emits BENCH_mapper.json:

    PYTHONPATH=src python -m benchmarks.perf_compare --mapper

Simulate mode benchmarks the vectorized exact simulator against the
per-iteration odometer on randomized schedules (bit-identical AccessCounts
asserted) and emits BENCH_simulate.json:

    PYTHONPATH=src python -m benchmarks.perf_compare --simulate

DSE mode runs the iso-throughput resource-allocation sweep (benchmarks/
fig_dse.py) and emits BENCH_dse.json:

    PYTHONPATH=src python -m benchmarks.perf_compare --dse
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.roofline import analyze_record


def load(path: str) -> dict:
    with open(path) as f:
        return analyze_record(json.load(f), path)


def compare(base: dict, var: dict) -> str:
    lines = [
        f"cell: {base['arch']} x {base['shape']} x {base['mesh']}",
        f"{'term':<14}{'baseline':>12}{'variant':>12}{'delta':>9}",
    ]
    for term in ("compute_s", "memory_s", "collective_s"):
        b, v = base[term], var[term]
        d = (v - b) / b * 100 if b else float("nan")
        lines.append(f"{term:<14}{b:>12.3e}{v:>12.3e}{d:>8.1f}%")
    lines.append(
        f"{'rf':<14}{base['roofline_fraction']:>12.3f}"
        f"{var['roofline_fraction']:>12.3f}"
    )
    lines.append(
        f"{'useful':<14}{base['useful_ratio']:>12.3f}"
        f"{var['useful_ratio']:>12.3f}"
    )
    lines.append(
        f"{'peakGiB':<14}{base['peak_gib']:>12.2f}{var['peak_gib']:>12.2f}"
    )
    return "\n".join(lines)


# --------------------------------------------------------------- mapper ----


def _mapper_layers():
    from repro.core.loopnest import conv_nest, fc_nest

    # small CNN with repeated shapes (real networks repeat layers, which is
    # what the optimizer's cross-sweep memoization exploits)
    return [
        conv_nest("c1", B=1, K=32, C=16, X=14, Y=14, FX=3, FY=3),
        conv_nest("c2", B=1, K=32, C=32, X=14, Y=14, FX=3, FY=3),
        conv_nest("c2b", B=1, K=32, C=32, X=14, Y=14, FX=3, FY=3),
        conv_nest("c3", B=1, K=64, C=32, X=7, Y=7, FX=3, FY=3),
        conv_nest("c3b", B=1, K=64, C=32, X=7, Y=7, FX=3, FY=3),
        fc_nest("fc", B=1, C=256, K=64),
    ]


def _mapper_hws():
    from repro.core.optimizer import HardwareConfig
    from repro.core.schedule import ArraySpec

    arr = ArraySpec(dims=(8, 8))
    return [
        HardwareConfig("rf64-buf32k", arr, rf_bytes=(64,),
                       buffer_bytes=(32 * 1024,)),
        HardwareConfig("rf128-buf64k", arr, rf_bytes=(128,),
                       buffer_bytes=(64 * 1024,)),
        HardwareConfig("rf256-buf128k", arr, rf_bytes=(256,),
                       buffer_bytes=(128 * 1024,)),
    ]


def bench_pricing_rate(n_target: int = 2000) -> dict:
    """Mappings priced per second: scalar evaluate() vs batched engine."""
    import itertools

    from repro.core.blocking import iter_blockings
    from repro.core.costmodel import BatchedCostModel
    from repro.core.energy import CostTable, evaluate
    from repro.core.loopnest import conv_nest
    from repro.core.optimizer import ck_dataflow, eyeriss_like

    nest = conv_nest("rate", B=1, K=64, C=64, X=14, Y=14, FX=3, FY=3)
    hw = eyeriss_like()
    levels = hw.levels()
    df = ck_dataflow(nest, hw.array)
    scheds = list(itertools.islice(
        iter_blockings(nest, levels, hw.array, df, max_choices_per_level=16),
        n_target,
    ))
    tbl = CostTable.for_levels(levels)

    t0 = time.perf_counter()
    scalar_e = [evaluate(s, tbl).energy_pj for s in scheds]
    t_scalar = time.perf_counter() - t0

    cm = BatchedCostModel(nest, levels, array=hw.array, spatial=df.assigns,
                          table=tbl)
    til, odr = cm.pack(scheds)
    t0 = time.perf_counter()
    batched_e = cm.energy(til, odr)
    t_batched = time.perf_counter() - t0

    assert all(a == b for a, b in zip(scalar_e, batched_e)), \
        "batched engine diverged from scalar oracle"
    n = len(scheds)
    return {
        "mappings": n,
        "scalar_per_s": n / t_scalar,
        "batched_per_s": n / t_batched,
        "speedup": t_scalar / t_batched,
    }


def bench_network_sweep() -> dict:
    """End-to-end hardware sweep: seed-equivalent scalar search vs the
    batched+pruned+memoized optimizer, asserting identical best energies."""
    from repro.core.blocking import search_blocking
    from repro.core.energy import CostTable
    from repro.core.optimizer import (
        ck_dataflow,
        clear_search_cache,
        optimize_network,
    )

    layers = _mapper_layers()
    hws = _mapper_hws()

    t0 = time.perf_counter()
    base_best = None
    for hw in hws:
        levels = hw.levels()
        table = CostTable.for_levels(levels)
        try:
            total = 0.0
            for nest in layers:
                df = ck_dataflow(nest, hw.array)
                res = search_blocking(
                    nest, levels, hw.array, df, table=table,
                    engine="scalar", prune=False,
                )
                total += res.best.energy_pj
        except ValueError:
            continue
        if base_best is None or total < base_best[0]:
            base_best = (total, hw.name)
    t_base = time.perf_counter() - t0

    clear_search_cache()
    t0 = time.perf_counter()
    res = optimize_network(layers, hws[0].array, hw_candidates=hws,
                           max_evals_per_layer=0)
    t_opt = time.perf_counter() - t0

    return {
        "layers": len(layers),
        "hw_candidates": len(hws),
        "baseline_s": t_base,
        "optimized_s": t_opt,
        "speedup": t_base / t_opt,
        "baseline_energy_pj": base_best[0],
        "optimized_energy_pj": res.total_energy_pj,
        "baseline_hw": base_best[1],
        "optimized_hw": res.hw.name,
        "identical_best": base_best[0] == res.total_energy_pj
        and base_best[1] == res.hw.name,
    }


# -------------------------------------------------------------- simulate ----


def _random_sim_schedules(n: int, seed: int = 0) -> list:
    """Randomized temporal schedules with 10^3-10^5 iterations each — big
    enough that the odometer's per-iteration cost dominates, small enough
    that the scalar baseline finishes."""
    import random

    from repro.core.loopnest import conv_nest, divisors, matmul_nest
    from repro.core.schedule import MemLevel, Schedule

    rng = random.Random(seed)
    levels = (
        MemLevel("RF", None, double_buffered=False, per_pe=True),
        MemLevel("BUF", None),
        MemLevel("DRAM", None),
    )

    def splits(bound: int, k: int) -> tuple[int, ...]:
        out = []
        rem = bound
        for _ in range(k - 1):
            f = rng.choice(divisors(rem))
            out.append(f)
            rem //= f
        out.append(rem)
        return tuple(out)

    scheds = []
    while len(scheds) < n:
        if rng.random() < 0.5:
            nest = conv_nest(
                "sim",
                B=rng.choice([1, 2]), K=rng.choice([4, 8, 16]),
                C=rng.choice([4, 8]), X=rng.choice([4, 7]),
                Y=rng.choice([4, 7]), FX=3, FY=3,
            )
        else:
            nest = matmul_nest(
                "sim", M=rng.choice([8, 16]), N=rng.choice([8, 16]),
                K=rng.choice([16, 32]),
            )
        tiling = {d: splits(nest.bounds[d], 3) for d in nest.dims}
        orders = tuple(
            tuple(rng.sample(list(nest.dims), len(nest.dims)))
            for _ in range(3)
        )
        scheds.append(
            Schedule(nest=nest, levels=levels, tiling=tiling, order=orders)
        )
    return scheds


def run_simulate(out_path: str, n: int = 40) -> dict:
    """Schedules simulated per second: odometer vs mixed-radix engine."""
    from repro.core.simulate import simulate

    scheds = _random_sim_schedules(n)
    iters = [s.temporal_trips() for s in scheds]

    t0 = time.perf_counter()
    scalar = [simulate(s, engine="scalar") for s in scheds]
    t_scalar = time.perf_counter() - t0

    t0 = time.perf_counter()
    vector = [simulate(s, engine="vector") for s in scheds]
    t_vector = time.perf_counter() - t0

    identical = scalar == vector
    if not identical:
        # not an assert: must hold under python -O too, and the JSON claim
        # below is acceptance evidence
        raise RuntimeError("vector simulator diverged from the odometer")
    result = {
        "schedules": n,
        "total_iterations": sum(iters),
        "max_iterations": max(iters),
        "scalar_per_s": n / t_scalar,
        "vector_per_s": n / t_vector,
        "speedup": t_scalar / t_vector,
        "bit_identical": identical,
    }
    print(f"simulate: {n} schedules ({sum(iters):.2e} total iters), "
          f"scalar {n/t_scalar:.1f}/s, vector {n/t_vector:.0f}/s, "
          f"speedup {t_scalar/t_vector:.0f}x")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {out_path}")
    return result


def run_mapper(out_path: str) -> dict:
    rate = bench_pricing_rate()
    sweep = bench_network_sweep()
    result = {"pricing": rate, "optimize_network": sweep}
    print(f"pricing: scalar {rate['scalar_per_s']:.0f}/s, "
          f"batched {rate['batched_per_s']:.0f}/s, "
          f"speedup {rate['speedup']:.1f}x")
    print(f"sweep: baseline {sweep['baseline_s']:.2f}s, "
          f"optimized {sweep['optimized_s']:.2f}s, "
          f"speedup {sweep['speedup']:.1f}x, "
          f"identical_best={sweep['identical_best']}")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--tag")
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mapper", action="store_true",
                    help="benchmark the batched mapping cost engine")
    ap.add_argument("--simulate", action="store_true",
                    help="benchmark the vectorized exact simulator")
    ap.add_argument("--dse", action="store_true",
                    help="run the resource-allocation DSE sweep benchmark")
    ap.add_argument("--workers", type=int, default=0,
                    help="process-pool workers for the DSE sweep")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.mapper:
        run_mapper(args.out or "BENCH_mapper.json")
        return
    if args.simulate:
        run_simulate(args.out or "BENCH_simulate.json")
        return
    if args.dse:
        from benchmarks.fig_dse import run as run_dse

        run_dse(args.out or "BENCH_dse.json", workers=args.workers)
        return
    if not args.cell or not args.tag:
        ap.error(
            "--cell and --tag are required (or pass --mapper/--simulate/--dse)"
        )
    arch, shape, mesh = args.cell
    base = load(os.path.join(args.dir, f"{arch}__{shape}__{mesh}.json"))
    var = load(
        os.path.join(args.dir, f"{arch}__{shape}__{mesh}__{args.tag}.json")
    )
    print(compare(base, var))


if __name__ == "__main__":
    main()
