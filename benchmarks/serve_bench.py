"""Serve benchmarks: scheduling, attention substrate, and decode scaling.

Phases, emitted together as BENCH_serve.json:

  * **continuous vs static** batching on a mixed-length synthetic workload
    at EQUAL slots — pure scheduling (both engines run the same jitted
    programs; the paper's utilization argument, Interstellar §6.3, at
    request granularity).
  * **flash-decoding vs masked-oracle attention** on the continuous engine
    at EQUAL slots and a serving-sized ``max_len`` — pure substrate (same
    scheduler; the delta is reading ``ceil(len/bk)`` KV blocks per slot vs
    scanning all ``max_len`` cached slots through a broadcast mask).
  * **decode-step latency scaling**: per-step decode latency at several
    cache fill levels and slot occupancies — flash-decoding step time must
    track the *live* length, not ``max_len``.
  * **paged vs contiguous KV layout**: (a) an agreement A/B at equal slots
    and equal pool — the paged engine must emit bitwise-identical tokens
    to the contiguous oracle (decode split pinned to the block size); (b)
    a shared-system-prompt workload at EQUAL KV HBM — the paged pool
    (refcounted blocks + prefix aliasing) must admit >= 1.5x the
    concurrent requests, flattening the queue-dominated TTFT tail (the
    paper's §6.3 over-provisioning argument: contiguous reserves
    ``max_len`` per slot, paged capacity tracks live tokens).
  * **admission storm** (unified scheduler): a warm decode ring is hit by
    long-prompt bulk admissions plus wall-clock interactive arrivals,
    served three ways — storm-free, chunked prefill (``prefill_chunk`` +
    ``token_budget``), and monolithic admission.  Chunking must cut
    interactive TTFT p95 >= 2x vs monolithic while decoder ITL p95 stays
    within 1.15x of storm-free, bitwise identical to the monolithic
    oracle (including mid-prefill lane preemptions) with zero leaked
    blocks.
  * **abft on vs off** (SDC defense in depth): paired clean-traffic A/B of
    the paged engine with ``abft="checksum"`` vs ``"off"`` on a scaled-up
    model (the surcharge is per-step work a dispatch-dominated smoke
    config cannot amortize) — ITL p95 ratio must stay <= 1.10x with the
    weight scrub amortized over ``scrub_every`` steps, the clean window
    must log zero detections (no false positives) with tokens bitwise
    identical to the unchecked engine, and seeded bit-flip episodes on
    the strict every-step-scrub config must detect 100% of fired compute
    faults and quarantine 100% of fired KV flips.

    PYTHONPATH=src python -m benchmarks.serve_bench [--requests N] [--out F]

All jitted paths are warmed with shape-identical traffic before any timed
window, so p95 measures scheduling, not compiles; latency is split into
TTFT (first token from arrival, queue wait included) and ITL (inter-token
gap) so queue depth no longer pollutes the per-token tail.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import tempfile
import time

import numpy as np


def make_workload(
    vocab: int,
    n: int,
    seed: int,
    id_base: int = 0,
    decode_range: tuple[int, int] = (4, 21),
):
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(0, vocab, rng.integers(3, 17)).astype(np.int32),
            max_new_tokens=int(rng.integers(*decode_range)),
            request_id=id_base + i,
        )
        for i in range(n)
    ]


def _pct(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * q))]


def _latency_stats(stamps: dict[int, list[float]]) -> dict[str, float]:
    """TTFT = first token from arrival (t=0 for the open-loop workload,
    queue wait included); ITL = inter-token gaps."""
    ttft = [ts[0] for ts in stamps.values() if ts]
    itl = [b - a for ts in stamps.values() for a, b in zip(ts, ts[1:])]
    return {
        "itl_p50_ms": _pct(itl, 0.50) * 1e3,
        "itl_p95_ms": _pct(itl, 0.95) * 1e3,
        "ttft_p50_ms": _pct(ttft, 0.50) * 1e3,
        "ttft_p95_ms": _pct(ttft, 0.95) * 1e3,
    }


def _drive(run_fn, requests) -> dict:
    stamps: dict[int, list[float]] = {}
    t0 = time.perf_counter()

    def on_token(rid, tok, idx, done):
        stamps.setdefault(rid, []).append(time.perf_counter() - t0)

    outs = run_fn(requests, on_token)
    wall = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    return {
        "tokens": total,
        "wall_s": wall,
        "tokens_per_s": total / wall,
        **_latency_stats(stamps),
        "outputs": [o.tolist() for o in outs],
    }


def _paired_ab(run_a, run_b, mk_requests, repeats: int):
    """Paired A/B: each repeat times A then B back-to-back and keeps the
    per-pair throughput ratio; the reported ratio is the median of pairs.
    The timed windows are fractions of a second on a shared noisy host —
    pairing cancels slow-host epochs that sequential best-of-N (measuring
    A minutes before B) cannot."""
    best_a = best_b = None
    ratios = []
    for r in range(repeats):
        a = _drive(run_a, mk_requests(r, 0))
        b = _drive(run_b, mk_requests(r, 1))
        ratios.append(a["tokens_per_s"] / b["tokens_per_s"])
        if best_a is None or a["tokens_per_s"] > best_a["tokens_per_s"]:
            best_a = a
        if best_b is None or b["tokens_per_s"] > best_b["tokens_per_s"]:
            best_b = b
    return best_a, best_b, sorted(ratios)[len(ratios) // 2]


# ---------------------------------------------------------- paged KV phase


def make_shared_prefix_workload(
    vocab: int,
    n: int,
    prefix_len: int,
    seed: int,
    id_base: int = 0,
    suffix_len: int = 8,
    max_new: int = 16,
):
    """N requests over one shared ``prefix_len``-token system prompt plus a
    short unique suffix — the million-user serving shape prefix sharing
    exists for."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len).astype(np.int32)
    return [
        Request(
            prompt=np.concatenate(
                [prefix, rng.integers(0, vocab, suffix_len).astype(np.int32)]
            ),
            max_new_tokens=max_new,
            request_id=id_base + i,
        )
        for i in range(n)
    ]


def bench_paged(
    cfg,
    params,
    slots: int,
    seed: int,
    n_requests: int,
    block_size: int = 16,
    shared_max_len: int = 576,
    shared_prefix: int = 512,
    shared_requests: int = 16,
    sched_factor: int = 4,
) -> dict:
    """Paged-vs-contiguous phases.

    **agreement**: equal slots, equal pool capacity, the contiguous decode
    split pinned to ``block_size`` — every generated token must be bitwise
    identical (the differential-oracle contract the fuzz suite enforces,
    re-proven on bench traffic).

    **shared_prefix**: equal KV HBM.  The contiguous engine gets ``slots``
    rings of ``shared_max_len``; the paged engine gets the SAME byte
    budget as a block pool (``slots * shared_max_len / block_size``
    blocks) and ``sched_factor * slots`` scheduling slots.  Because the
    512-token system prompt is aliased across requests and decode blocks
    are allocated for live tokens only, the paged engine admits several
    times more concurrent requests in one wave, flattening the TTFT tail
    (contiguous staggers admissions ``slots`` at a time, so late requests
    queue behind whole decode generations)."""
    from repro.serve.engine import Engine, ServeConfig

    # --- agreement at equal capacity -------------------------------------
    mk = lambda i: make_workload(cfg.vocab, n_requests, seed, id_base=i)
    cont = Engine(
        cfg,
        params,
        ServeConfig(
            batch=slots,
            max_len=64,
            seed=seed,
            prefill_bucket=16,
            decode_block=block_size,
        ),
    )
    paged = Engine(
        cfg,
        params,
        ServeConfig(
            batch=slots,
            max_len=64,
            seed=seed,
            prefill_bucket=16,
            kv_layout="paged",
            block_size=block_size,
        ),
    )
    cont.run(mk(50_000))  # warm both
    paged.run(mk(50_000))
    a = _drive(lambda rs, cb: cont.run(rs, on_token=cb), mk(0))
    b = _drive(lambda rs, cb: paged.run(rs, on_token=cb), mk(0))
    agree = a.pop("outputs") == b.pop("outputs")
    agreement = {
        "bitwise_identical": agree,
        "block_size": block_size,
        "contiguous_tokens_per_s": a["tokens_per_s"],
        "paged_tokens_per_s": b["tokens_per_s"],
    }

    # --- shared prefix at equal KV HBM -----------------------------------
    def shared_run(kv_layout: str):
        if kv_layout == "paged":
            scfg = ServeConfig(
                batch=sched_factor * slots,
                max_len=shared_max_len,
                seed=seed,
                prefill_bucket=16,
                kv_layout="paged",
                block_size=block_size,
                # equal HBM: the pool holds exactly the contiguous
                # engine's slots * max_len KV positions (+ sink block)
                num_blocks=slots * shared_max_len // block_size + 1,
            )
        else:
            scfg = ServeConfig(
                batch=slots,
                max_len=shared_max_len,
                seed=seed,
                prefill_bucket=16,
                decode_block=block_size,
            )
        eng = Engine(cfg, params, scfg)
        eng.run(
            make_shared_prefix_workload(
                cfg.vocab, shared_requests, shared_prefix, seed, id_base=60_000
            )
        )  # warm every shape
        eng.stats["peak_active"] = 0
        reqs = make_shared_prefix_workload(
            cfg.vocab, shared_requests, shared_prefix, seed
        )
        res = _drive(lambda rs, cb: eng.run(rs, on_token=cb), reqs)
        return {
            "peak_concurrent": eng.stats["peak_active"],
            "tokens_per_s": res["tokens_per_s"],
            "ttft_p50_ms": res["ttft_p50_ms"],
            "ttft_p95_ms": res["ttft_p95_ms"],
            "outputs": res.pop("outputs"),
        }

    sc = shared_run("contiguous")
    sp = shared_run("paged")
    shared_agree = sc.pop("outputs") == sp.pop("outputs")
    conc_ratio = sp["peak_concurrent"] / max(1, sc["peak_concurrent"])
    shared = {
        "requests": shared_requests,
        "prefix_len": shared_prefix,
        "max_len": shared_max_len,
        "kv_hbm_token_budget": slots * shared_max_len,
        "contiguous": sc,
        "paged": sp,
        "bitwise_identical": shared_agree,
        "admitted_concurrency_ratio": conc_ratio,
        "ttft_p95_speedup": sc["ttft_p95_ms"] / max(1e-9, sp["ttft_p95_ms"]),
    }
    return {"agreement": agreement, "shared_prefix": shared}


# ------------------------------------------------------- fault-storm phase


def _storm_drive(eng, reqs, hp, cancel_ids, burst: int = 3) -> dict:
    """Drive ``reqs`` through a live engine with incremental submission
    (``burst`` per step), injecting the fault schedule: ``cancel_ids``
    are cancelled once active with >= 2 tokens out, and the ``hp``
    requests land only when every slot is occupied (so their priority has
    to preempt).  The no-fault baseline uses the SAME loop with empty
    fault inputs — identical submission dynamics, so the survivor ITL
    comparison isolates the faults, not the arrival pattern."""
    from repro.serve.engine import RequestStatus

    stamps: dict[int, list[float]] = {}
    t0 = time.perf_counter()

    def on_token(rid, tok, idx, done):
        stamps.setdefault(rid, []).append(time.perf_counter() - t0)

    pending = list(reqs)
    hp = list(hp)
    cancel_ids = set(cancel_ids)
    rids = [r.request_id for r in reqs] + [r.request_id for r in hp]
    open_preempt: dict[int, float] = {}
    recoveries: list[tuple[int, float, float]] = []  # (rid, t_gone, t_back)
    steps = 0
    while pending or hp or eng._slots or eng._waiting:
        for _ in range(burst):
            if pending:
                eng.submit(pending.pop(0))
        if hp and not pending and not eng._free:
            # high occupancy reached: the latecomers arrive all at once
            for r in hp:
                eng.submit(r)
            hp.clear()
        for rid in sorted(cancel_ids):
            if (
                eng.status(rid) == RequestStatus.ACTIVE
                and len(stamps.get(rid, [])) >= 2
            ):
                eng.cancel(rid)
                cancel_ids.discard(rid)
        active_before = {
            r for r in rids if eng.status(r) == RequestStatus.ACTIVE
        }
        eng.step(on_token)
        now = time.perf_counter() - t0
        for rid in active_before:
            if (
                eng.status(rid) == RequestStatus.PREEMPTED
                and rid not in open_preempt
            ):
                open_preempt[rid] = now
        for rid, t_gone in list(open_preempt.items()):
            ts = stamps.get(rid, [])
            if ts and ts[-1] > t_gone:  # first fresh token after recovery
                recoveries.append((rid, t_gone, ts[-1]))
                del open_preempt[rid]
        steps += 1
        assert steps < 10_000, "fault storm failed to drain"
    return {
        "wall_s": time.perf_counter() - t0,
        "stamps": stamps,
        "recoveries": recoveries,
    }


def bench_fault_storm(
    cfg,
    params,
    slots: int,
    seed: int,
    n_requests: int = 24,
    block_size: int = 16,
    max_len: int = 64,
    cancel_fraction: float = 0.10,
    deadline_fraction: float = 0.20,
    hp_requests: int = 3,
    repeats: int = 3,
) -> dict:
    """Request-lifecycle robustness under fire, measured: the mixed
    workload runs unfaulted (baseline) and through a storm — ~10% of
    requests cancelled mid-generation, ~20% deadline-bound, and a late
    wave of high-priority arrivals at full occupancy forcing real
    preemptions.  Emits leaked-block count (must be zero), preemption
    recovery latency, survivor throughput/ITL (recovery gaps excluded —
    they are reported as recovery latency, not inter-token jitter), and
    whether every survivor's output stayed bitwise equal to its unfaulted
    baseline run (deterministic sampling makes the two comparable
    token-for-token).  Timing pairs baseline/storm back-to-back per repeat
    and reports the median-ratio pair — same rationale as _paired_ab; the
    invariant fields (statuses, leaks, bitwise) are identical across
    repeats because the fault schedule is a pure function of the seed."""
    from repro.serve.engine import Engine, Request, RequestStatus, ServeConfig

    scfg = ServeConfig(
        batch=slots,
        max_len=max_len,
        seed=seed,
        prefill_bucket=16,
        kv_layout="paged",
        block_size=block_size,
    )
    eng = Engine(cfg, params, scfg)
    free0 = eng.pool.free_blocks
    rng = np.random.default_rng(seed)

    def faulted(reqs, deadline_rng):
        """Attach the deadline mix and split off the high-priority tail."""
        body = [
            Request(
                r.prompt,
                r.max_new_tokens,
                request_id=r.request_id,
                deadline_steps=(
                    int(deadline_rng.integers(6, 25))
                    if deadline_rng.random() < deadline_fraction
                    else None
                ),
            )
            for r in reqs[:n_requests]
        ]
        tail = [
            Request(
                r.prompt, r.max_new_tokens, request_id=r.request_id, priority=5
            )
            for r in reqs[n_requests:]
        ]
        return body, tail

    def pick_cancels(reqs, cancel_rng):
        # target only deadline-free requests: a target that FAILs its
        # deadline before reaching two tokens would never get cancelled,
        # silently thinning the advertised cancel mix
        pool = [r.request_id for r in reqs if r.deadline_steps is None]
        n_cancel = min(
            len(pool), max(1, int(round(cancel_fraction * n_requests)))
        )
        return cancel_rng.choice(pool, size=n_cancel, replace=False).tolist()

    base = make_workload(cfg.vocab, n_requests + hp_requests, seed)
    # warm with a full faulted pass: incremental admission-group shapes AND
    # the cancel/evict/preempt/replay paths all compile before either timed
    # pass, so the storm-vs-baseline delta is scheduling, not jit caches
    warm = make_workload(
        cfg.vocab, n_requests + hp_requests, seed, id_base=70_000
    )
    wbody, wtail = faulted(warm, np.random.default_rng(seed))
    _storm_drive(
        eng, wbody, hp=wtail, cancel_ids=pick_cancels(wbody, rng)
    )
    for r in warm:
        eng.pop_result(r.request_id)

    pairs = []
    for _ in range(repeats):
        # --- no-fault baseline (same drive loop, zero faults) -------------
        run0 = _storm_drive(eng, base, hp=[], cancel_ids=[])
        base_out = {r.request_id: eng.pop_result(r.request_id) for r in base}
        assert all(
            o.status == RequestStatus.FINISHED for o in base_out.values()
        ), "baseline pass must finish everything"
        base_itl = _latency_stats(run0["stamps"])
        base_tokens = sum(len(o) for o in base_out.values())

        # --- the storm ----------------------------------------------------
        storm, hp = faulted(base, np.random.default_rng(seed))
        cancel_ids = pick_cancels(storm, np.random.default_rng(seed + 1))
        run1 = _storm_drive(eng, storm, hp=hp, cancel_ids=cancel_ids)

        results = {r.request_id: eng.pop_result(r.request_id) for r in base}
        leaked = free0 - eng.pool.free_blocks
        statuses: dict[str, int] = {}
        for res in results.values():
            statuses[res.status.value] = statuses.get(res.status.value, 0) + 1

        survivors = [
            rid
            for rid, res in results.items()
            if res.status == RequestStatus.FINISHED
        ]
        bitwise = all(
            results[rid].tolist() == base_out[rid].tolist()
            for rid in survivors
        )
        # survivor ITL: skip gaps straddling that request's own preemption —
        # the engine was deliberately not running it; that cost is reported
        # as recovery latency, not inter-token jitter
        gone_at: dict[int, list[float]] = {}
        for rid, t_gone, _ in run1["recoveries"]:
            gone_at.setdefault(rid, []).append(t_gone)
        itl = []
        for rid in survivors:
            ts = run1["stamps"].get(rid, [])
            for a, b in zip(ts, ts[1:]):
                if any(a <= t <= b for t in gone_at.get(rid, ())):
                    continue
                itl.append(b - a)
        rec_ms = [
            (t_back - t_gone) * 1e3 for _, t_gone, t_back in run1["recoveries"]
        ]
        surv_tokens = sum(len(results[rid]) for rid in survivors)
        surv_itl_p95 = _pct(itl, 0.95) * 1e3
        pairs.append(
            {
                "statuses": statuses,
                "leaked_blocks": leaked,
                "free_blocks_final": eng.pool.free_blocks,
                "preemptions": sum(
                    res.preemptions for res in results.values()
                ),
                "recovered": len(run1["recoveries"]),
                "recovery_latency_p50_ms": _pct(rec_ms, 0.50),
                "recovery_latency_max_ms": max(rec_ms) if rec_ms else 0.0,
                "survivors": len(survivors),
                "survivor_tokens": surv_tokens,
                "survivor_tokens_per_s": surv_tokens / run1["wall_s"],
                "survivor_itl_p50_ms": _pct(itl, 0.50) * 1e3,
                "survivor_itl_p95_ms": surv_itl_p95,
                "bitwise_survivors_match_baseline": bitwise,
                "baseline": {
                    "tokens_per_s": base_tokens / run0["wall_s"],
                    "itl_p50_ms": base_itl["itl_p50_ms"],
                    "itl_p95_ms": base_itl["itl_p95_ms"],
                },
                "survivor_itl_p95_vs_baseline": surv_itl_p95
                / max(1e-9, base_itl["itl_p95_ms"]),
            }
        )

    by_ratio = sorted(pairs, key=lambda p: p["survivor_itl_p95_vs_baseline"])
    median = by_ratio[len(by_ratio) // 2]
    return {
        "requests": n_requests,
        "hp_requests": hp_requests,
        "cancel_fraction": cancel_fraction,
        "deadline_fraction": deadline_fraction,
        "repeats": repeats,
        "free_blocks_initial": free0,
        # invariants must hold on EVERY pair, not just the reported one
        "leaked_blocks": max(p["leaked_blocks"] for p in pairs),
        "bitwise_survivors_match_baseline": all(
            p["bitwise_survivors_match_baseline"] for p in pairs
        ),
        "itl_ratio_runs": [
            p["survivor_itl_p95_vs_baseline"] for p in pairs
        ],
        **{
            k: median[k]
            for k in median
            if k not in ("leaked_blocks", "bitwise_survivors_match_baseline")
        },
    }


# ---------------------------------------------------- crash-recovery phase


def bench_crash_recovery(
    cfg,
    params,
    slots: int,
    seed: int,
    n_requests: int = 16,
    max_len: int = 64,
    block_size: int = 16,
    overhead_snapshot_every: int = 32,
    drill_snapshot_every: int = 8,
    journal_fsync_every: int = 8,
    # the overhead gate rides a p95-of-p95 ratio, so it takes the median
    # of more pairs than the other phases to shed scheduler-noise tails
    repeats: int = 5,
) -> dict:
    """Durability cost + recovery drill (serve/recovery.py).

    **overhead**: paired A/B of the same engine config with and without
    snapshots+journal at the shipped defaults (snapshot cadence 32, group
    commit: journal flushed every step — process-crash safe — and fsync'd
    every 8 — at most 8 steps of token deltas exposed to power loss;
    client-visible submit/cancel/pop records always force a sync).
    Reports the median per-pair survivor ITL p95 ratio — the steady-state
    price of crash consistency.  Snapshots land on a RAM-backed fs when
    available so the phase measures engine overhead, not the CI runner's
    disk.

    **recovery**: a simulated SIGKILL mid-run (snapshot published, journal
    tail fsync'd, nothing closed), then a timed ``restore_engine`` and
    teacher-forced replay until ``replay_lag`` hits zero — the
    time-to-readmit a survivor.  The restored run must finish every
    request bitwise-identical to a never-crashed run; ``replay_mismatches``
    counts violations and must be zero."""
    from repro.serve import recovery
    from repro.serve.engine import Engine, RequestStatus, ServeConfig

    ram = os.path.isdir("/dev/shm")
    root = tempfile.mkdtemp(
        prefix="repro_recovery_", dir="/dev/shm" if ram else None
    )
    common = dict(
        batch=slots,
        max_len=max_len,
        seed=seed,
        prefill_bucket=16,
        kv_layout="paged",
        block_size=block_size,
    )
    try:
        # --- steady-state overhead ---------------------------------------
        on = Engine(
            cfg,
            params,
            ServeConfig(
                snapshot_dir=os.path.join(root, "overhead"),
                snapshot_every=overhead_snapshot_every,
                journal_fsync_every=journal_fsync_every,
                **common,
            ),
        )
        off = Engine(cfg, params, ServeConfig(**common))
        warm = make_workload(cfg.vocab, n_requests, seed, id_base=80_000)
        on.run(list(warm))
        off.run(list(warm))
        pairs = []
        for r in range(repeats):
            a = _drive(
                lambda rs, cb: on.run(rs, on_token=cb),
                make_workload(cfg.vocab, n_requests, seed, id_base=81_000),
            )
            b = _drive(
                lambda rs, cb: off.run(rs, on_token=cb),
                make_workload(cfg.vocab, n_requests, seed, id_base=82_000),
            )
            a.pop("outputs")
            b.pop("outputs")
            pairs.append((a["itl_p95_ms"] / max(1e-9, b["itl_p95_ms"]), a, b))
        pairs.sort(key=lambda p: p[0])
        ratio, med_a, med_b = pairs[len(pairs) // 2]
        keys = ("tokens_per_s", "itl_p50_ms", "itl_p95_ms")
        overhead = {
            "snap_on": {k: med_a[k] for k in keys},
            "snap_off": {k: med_b[k] for k in keys},
            "itl_p95_ratio_runs": [p[0] for p in pairs],
            "snapshot_itl_p95_vs_off": ratio,
            "snapshots_taken": int(on.stats["snapshots"]),
        }
        on.close()

        # --- kill + timed restore drill ----------------------------------
        reqs = make_workload(cfg.vocab, n_requests, seed)
        want = {
            r.request_id: o.tolist() for r, o in zip(reqs, off.run(list(reqs)))
        }
        scfg = ServeConfig(
            snapshot_dir=os.path.join(root, "drill"),
            snapshot_every=drill_snapshot_every,
            **common,
        )
        eng = Engine(cfg, params, scfg)
        for r in reqs:
            eng.submit(r)
        crash_step = drill_snapshot_every + drill_snapshot_every // 2
        for _ in range(crash_step):
            eng.step()
        eng.recovery.wait()
        # simulated SIGKILL: the engine object is simply abandoned

        t0 = time.perf_counter()
        eng2, report = recovery.restore_engine(cfg, params, scfg)
        restore_s = time.perf_counter() - t0
        lag0 = recovery.replay_lag(eng2)
        t1 = time.perf_counter()
        while recovery.replay_lag(eng2) > 0 and eng2.step():
            pass
        catchup_s = time.perf_counter() - t1
        while eng2.step():
            pass
        finished = mismatches = 0
        for r in reqs:
            res = eng2.pop_result(r.request_id)
            if (
                res.status == RequestStatus.FINISHED
                and res.tolist() == want[r.request_id]
            ):
                finished += 1
            else:
                mismatches += 1  # the drill injects no faults: all must land
        leaked = eng2.pool.num_blocks - 1 - eng2.pool.free_blocks
        eng2.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return {
        "snapshot_dir_fs": "ram" if ram else "disk",
        "overhead_snapshot_every": overhead_snapshot_every,
        "drill_snapshot_every": drill_snapshot_every,
        "journal_fsync_every": journal_fsync_every,
        "repeats": repeats,
        "overhead": overhead,
        "recovery": {
            "requests": n_requests,
            "crash_step": crash_step,
            "source": report.source,
            "snapshot_key": (
                None
                if report.snapshot_key is None
                else list(report.snapshot_key)
            ),
            "journal_segments": report.segments,
            "journal_records": report.records,
            "tokens_replayed": report.tokens_replayed,
            "replay_lag_at_restore": lag0,
            "restore_ms": restore_s * 1e3,
            "replay_catchup_ms": catchup_s * 1e3,
            "recovery_time_to_readmit_ms": (restore_s + catchup_s) * 1e3,
            "finished": finished,
            "replay_mismatches": mismatches,
            "bitwise_survivors": mismatches == 0,
            "leaked_blocks": leaked,
        },
    }


# ---------------------------------------------------------- sdc/abft phase


def bench_sdc(
    cfg,
    params,
    slots: int,
    seed: int,
    n_requests: int = 16,
    max_len: int = 64,
    block_size: int = 8,
    repeats: int = 3,
    episodes: int = 4,
    overhead_cfg=None,
    overhead_slots: int | None = None,
    scrub_every: int = 100,
) -> dict:
    """ABFT price + proof (kernels/abft.py, the serve-engine SDC pipeline).

    **overhead**: paired A/B of the same paged engine config with
    ``abft="checksum"`` vs ``abft="off"`` on clean traffic — the median
    per-pair ITL p95 ratio is the steady-state price of checksummed
    matmuls, the decode-attention fingerprint, and the amortized weight
    scrub (``scrub_every``; every 1/scrub_every-th step re-reads all
    params).  When ``overhead_cfg`` is given the pair runs on that
    (larger) model with fresh params: the ABFT surcharge is per-step
    work that a dispatch-dominated smoke model cannot amortize, so the
    price is only meaningful where decode is compute/memory bound.  The
    clean window doubles as the false-positive gate: the abft engine's
    detection counters must not move, and its tokens must stay bitwise
    identical to the unchecked engine (the checksum side-channel must
    never perturb the product).

    **detection**: seeded fault episodes through ``chaos.run_sdc_episode``
    on the small config (default ``scrub_every=1``, the strictest
    setting) — deterministic (n_compute, n_kv) mixes so both fault
    surfaces fire even at a reduced episode count.  Every episode
    internally asserts the full detect -> localize -> retry -> quarantine
    contract against a contiguous bitwise oracle; the emitted rates
    re-state the aggregate so check_regress can gate them from the
    committed JSON."""
    from repro.arch.model_zoo import build
    from repro.serve import chaos
    from repro.serve.engine import (
        Engine,
        KernelConfig,
        KVConfig,
        SchedulerConfig,
        ServeConfig,
    )

    ocfg, oparams, oslots = cfg, params, slots
    if overhead_cfg is not None:
        import jax

        ocfg = overhead_cfg
        oparams = build(ocfg).init(jax.random.PRNGKey(seed))
        oslots = overhead_slots or slots

    common = dict(max_len=max_len, seed=seed)
    osched = SchedulerConfig(batch=oslots, prefill_bucket=16)
    paged = KVConfig(layout="paged", block_size=block_size)
    # long decodes so ITL gaps dominate TTFT noise and the 1/scrub_every
    # slow-step fraction sits below the p95 cut instead of straddling it
    decode_range = (24, 41)

    with Engine(
        ocfg,
        oparams,
        ServeConfig(
            scheduler=osched,
            kv=paged,
            kernel=KernelConfig(abft="checksum", scrub_every=scrub_every),
            **common,
        ),
    ) as on, Engine(
        ocfg, oparams, ServeConfig(scheduler=osched, kv=paged, **common)
    ) as off:
        warm = make_workload(
            ocfg.vocab, n_requests, seed, id_base=95_000, decode_range=decode_range
        )
        on.run(list(warm))
        off.run(list(warm))

        # --- clean paired overhead + false-positive window ----------------
        det0 = on.stats["sdc_detected"] + on.stats["quarantined"]
        pairs = []
        for r in range(repeats):
            reqs = make_workload(
                ocfg.vocab,
                n_requests,
                seed,
                id_base=r * 1000,
                decode_range=decode_range,
            )
            a = _drive(lambda rs, cb: on.run(rs, on_token=cb), list(reqs))
            b = _drive(lambda rs, cb: off.run(rs, on_token=cb), list(reqs))
            agree = a.pop("outputs") == b.pop("outputs")
            pairs.append(
                (a["itl_p95_ms"] / max(1e-9, b["itl_p95_ms"]), a, b, agree)
            )
        pairs.sort(key=lambda p: p[0])
        ratio, med_a, med_b, _ = pairs[len(pairs) // 2]
        clean_detections = (
            on.stats["sdc_detected"] + on.stats["quarantined"] - det0
        )
        keys = ("tokens_per_s", "itl_p50_ms", "itl_p95_ms")
        overhead = {
            "abft_on": {k: med_a[k] for k in keys},
            "abft_off": {k: med_b[k] for k in keys},
            "itl_p95_ratio_runs": [p[0] for p in pairs],
            "abft_itl_p95_vs_off": ratio,
        }

    # --- seeded detection episodes (small config, scrub every step) -------
    with Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(batch=slots, prefill_bucket=16),
            kv=paged,
            kernel=KernelConfig(abft="checksum"),
            **common,
        ),
    ) as ep_on, Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(batch=slots, prefill_bucket=16),
            kv=KVConfig(decode_block=block_size),
            **common,
        ),
    ) as oracle_eng:
        mixes = [(1, 1), (2, 1), (1, 2), (2, 0)]
        reports = []
        for ep in range(episodes):
            n_compute, n_kv = mixes[ep % len(mixes)]
            ep_seed = seed + chaos.SEED_STRIDE + ep
            rng = np.random.default_rng(ep_seed)
            reqs = chaos.make_sdc_workload(rng, cfg.vocab, max_len)
            want = chaos.oracle_outputs(oracle_eng, reqs)
            reports.append(
                chaos.run_sdc_episode(
                    ep_on, want, reqs, ep_seed, n_compute=n_compute, n_kv=n_kv
                )
            )
        fired_compute = sum(r.injected["compute"] for r in reports)
        fired_kv = sum(r.injected["kv"] for r in reports)
        detection = {
            "episodes": episodes,
            "injected_compute": fired_compute,
            "detected": sum(r.detected for r in reports),
            "detection_rate": (
                sum(r.detected for r in reports) / fired_compute
                if fired_compute
                else 1.0
            ),
            "injected_kv": fired_kv,
            "quarantined": sum(r.quarantined for r in reports),
            "kv_detection_rate": (
                sum(r.quarantined for r in reports) / fired_kv
                if fired_kv
                else 1.0
            ),
            "retried": sum(r.retried for r in reports),
        }

    return {
        "abft_mode": "checksum",
        "block_size": block_size,
        "requests": n_requests,
        "repeats": repeats,
        "scrub_every": scrub_every,
        "overhead_model": ocfg.name,
        "overhead_slots": oslots,
        "overhead": overhead,
        # invariants: every pair bitwise, zero detections on clean traffic
        "bitwise_identical_to_off": all(p[3] for p in pairs),
        "clean_false_positives": clean_detections,
        "detection": detection,
    }


# ------------------------------------------------ admission-storm phase


def _admission_pass(eng, decoders, storm, ramp_steps: int, window: int) -> dict:
    """One measured pass: submit the decode ring at t=0, ramp it for
    ``ramp_steps`` so every slot is live with a grown cache, then run
    exactly ``window`` more steps while the ``storm`` schedule (a list of
    ``(arrival_seconds, Request)`` relative to ramp end) lands.  Arrivals
    are WALL-CLOCK, not step-aligned: a request whose arrival time falls
    inside a long step is submitted at the next boundary, and its TTFT is
    measured from the intended arrival — exactly the latency a client
    sees when its request lands mid-prefill on a monolithic engine.
    Every storm request must reach a terminal state inside the window;
    the decoders are cancelled at the end (they are background load, not
    subjects).  Fixing the step count makes passes comparable: the
    storm-free baseline, the chunked storm, and the monolithic storm all
    see the same decode-ring fill trajectory, so ITL deltas are the
    storm, not cache growth."""
    from repro.serve.engine import RequestStatus

    stamps: dict[int, list[float]] = {}
    submit_t: dict[int, float] = {}
    t0 = time.perf_counter()

    def on_token(rid, tok, idx, done):
        stamps.setdefault(rid, []).append(time.perf_counter() - t0)

    for r in decoders:
        submit_t[r.request_id] = time.perf_counter() - t0
        eng.submit(r)
    for _ in range(ramp_steps):
        eng.step(on_token)
    ramp_t = time.perf_counter() - t0

    storm = sorted(storm, key=lambda e: e[0])
    i = 0

    def submit_due():
        nonlocal i
        now = time.perf_counter() - t0
        while i < len(storm) and ramp_t + storm[i][0] <= now:
            r = storm[i][1]
            # latency is charged from the client's arrival, not from the
            # step boundary where the engine could first accept it
            submit_t[r.request_id] = ramp_t + storm[i][0]
            eng.submit(r)
            i += 1

    for _ in range(window):
        submit_due()
        eng.step(on_token)
    fixed_end = time.perf_counter() - t0
    # grace: wall-clock arrivals shift relative to step counts on slower
    # or faster hosts, so stragglers get extra drain steps; the ITL
    # comparison below reads ONLY the fixed window, so grace steps never
    # skew the storm-vs-baseline numbers
    terminal = (
        RequestStatus.FINISHED,
        RequestStatus.CANCELLED,
        RequestStatus.FAILED,
        RequestStatus.REJECTED,
    )
    for _ in range(4 * window + 100):
        if i < len(storm):
            # an idle engine steps in microseconds, so a small schedule can
            # exhaust the grace budget before the next wall-clock arrival is
            # even due; grace is unmeasured, so fast-forward to it instead
            lag = ramp_t + storm[i][0] - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
        submit_due()
        if i == len(storm) and all(
            eng.status(r.request_id) in terminal for _, r in storm
        ):
            break
        eng.step(on_token)
    else:
        live = [
            r.request_id
            for _, r in storm
            if eng.status(r.request_id) not in terminal
        ]
        raise AssertionError(
            f"storm requests {live} still live after the grace window"
        )
    for r in decoders:
        eng.cancel(r.request_id)
    results = {
        r.request_id: eng.pop_result(r.request_id)
        for r in decoders + [r for _, r in storm]
    }
    ttft = {
        rid: ts[0] - submit_t[rid] for rid, ts in stamps.items() if ts
    }
    # decoder ITL over the fixed storm window only: gaps from the
    # background ring the storm disturbs, ramp and grace excluded
    itl = [
        b - a
        for r in decoders
        for a, b in zip(
            stamps.get(r.request_id, []), stamps.get(r.request_id, [])[1:]
        )
        if a > ramp_t and b < fixed_end
    ]
    return {"results": results, "ttft": ttft, "itl": itl}


def bench_admission_storm(
    cfg,
    params,
    seed: int,
    slots: int = 24,
    max_len: int = 1024,
    block_size: int = 16,
    prefill_chunk: int = 8,
    n_decoders: int = 20,
    # a deep ramp grows the ring's caches first, so the fixed per-step cost
    # of a chunk is amortized against realistic decode work — shallow rings
    # overstate the ITL ratio (the chunk is then the step's biggest term)
    ramp_steps: int = 400,
    n_bulk: int = 2,
    bulk_prompt: int = 1000,
    bulk_new: int = 4,
    inter_offsets: tuple = (0.01, 0.05, 0.10, 0.6, 1.0, 1.4),
    inter_new: int = 8,
    window: int = 400,
    mono_window: int = 130,
    repeats: int = 3,
) -> dict:
    """The unified scheduler's reason to exist, measured: a live decode
    ring (``n_decoders`` requests mid-generation) is hit by an admission
    storm — ``n_bulk`` long prompts in a 50ms burst plus interactive
    latecomers (priority 5, tiny prompts) whose wall-clock arrivals land
    while the bulk prompts are being absorbed: on the monolithic engine
    that means mid-prefill, the worst case, because the engine cannot
    accept (let alone answer) anything until the running
    ~``bulk_prompt``-token step completes.  The same schedule runs three
    ways on fixed step windows:

      * storm-free (chunked engine, no storm): the ITL reference.
      * chunked storm: ``prefill_chunk``/``token_budget`` bound prefill
        work per step, and interactive arrivals preempt the bulk lane at
        chunk granularity (re-prefill from scratch, the PR-6 idiom).
      * monolithic storm (``prefill_chunk=0``, the bitwise oracle): each
        bulk admission prefills ~``bulk_prompt`` tokens inside one step,
        stalling every token in flight.

    Gates (checked by check_regress): interactive TTFT p95 cut >= 2x vs
    monolithic, decoder ITL p95 <= 1.15x the storm-free baseline, every
    request bitwise-identical across chunked and monolithic (including
    the bulks preempted mid-prefill), zero leaked blocks, and at least
    one lane preemption actually exercised.  Bulk TTFT is reported too —
    it gets *worse* under chunking; that is the advertised trade.  The
    decode ring is sized so a chunk rides inside the step's latency
    budget (the operating point the token_budget knob exists for); the
    smoke model's step cost is dispatch-dominated, so flatness requires
    a genuinely busy ring, same as production.  Timing is paired
    back-to-back per repeat with the median ratio reported
    (cf. _paired_ab); invariants must hold on every repeat."""
    from repro.serve.engine import (
        Engine,
        KVConfig,
        Request,
        RequestStatus,
        SchedulerConfig,
        ServeConfig,
    )

    decoder_new = ramp_steps + window + 20
    rng = np.random.default_rng(seed)

    def mk_requests(id_base: int):
        """One deterministic workload (same prompts/ids across engines —
        sampling folds (seed, rid, t), so equal ids make the monolithic
        run the bitwise oracle of the chunked one)."""
        r = np.random.default_rng(seed + 17)
        decoders = [
            Request(
                r.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=decoder_new,
                request_id=id_base + i,
                priority=9,  # the ring must never be the preemption victim
            )
            for i in range(n_decoders)
        ]
        storm = []
        for i in range(n_bulk):
            storm.append(
                (
                    0.05 * i,
                    Request(
                        r.integers(
                            0, cfg.vocab, bulk_prompt + int(r.integers(0, 8))
                        ).astype(np.int32),
                        max_new=bulk_new,
                        request_id=id_base + 100 + i,
                        priority=0,
                    ),
                )
            )
        for j, off in enumerate(inter_offsets):
            storm.append(
                (
                    off,
                    Request(
                        r.integers(
                            0, cfg.vocab, 5 + int(r.integers(0, 4))
                        ).astype(np.int32),
                        max_new=inter_new,
                        request_id=id_base + 200 + j,
                        priority=5,
                    ),
                )
            )
        return decoders, storm

    common = dict(max_len=max_len, seed=seed)
    kv = KVConfig(layout="paged", block_size=block_size)
    chunked = Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(
                batch=slots,
                prefill_bucket=16,
                prefill_chunk=prefill_chunk,
                token_budget=prefill_chunk,
            ),
            kv=kv,
            **common,
        ),
    )
    mono = Engine(
        cfg,
        params,
        ServeConfig(
            scheduler=SchedulerConfig(batch=slots, prefill_bucket=16),
            kv=kv,
            **common,
        ),
    )
    free0 = {"chunked": chunked.pool.free_blocks, "mono": mono.pool.free_blocks}

    # warm both engines with a 1-bulk miniature of the schedule: compiles
    # the chunk/install/admission/decode programs before any timed
    # window, including the group shapes wall-clock bunching can produce
    # in the monolithic engine (interactive admission groups of 1-3) and
    # the chunked lane preemption/restart path
    wd, ws = mk_requests(90_000)
    wbulk = next(r for _, r in ws if r.request_id == 90_100)
    winters = [r for _, r in ws if r.request_id >= 90_200]
    woff = (0.0, 0.0, 0.0, 0.3, 0.3, 0.6)
    wstorm = [(0.0, wbulk)] + [
        (woff[j % len(woff)], r) for j, r in enumerate(winters)
    ]
    warm_w = 2 * bulk_prompt // prefill_chunk + 4 * bulk_new + 80
    _admission_pass(chunked, wd, wstorm, 8, warm_w)
    _admission_pass(mono, wd, wstorm, 8, warm_w // 2)

    passes = []
    inter_ids = lambda base: [
        base + 200 + j for j in range(len(inter_offsets))
    ]
    bulk_ids = lambda base: [base + 100 + i for i in range(n_bulk)]
    for _ in range(repeats):
        decoders, storm = mk_requests(0)
        free_run = _admission_pass(chunked, decoders, [], ramp_steps, window)
        p0 = chunked.stats["preempted"]
        storm_run = _admission_pass(chunked, decoders, storm, ramp_steps, window)
        lane_preempts = chunked.stats["preempted"] - p0
        mono_run = _admission_pass(mono, decoders, storm, ramp_steps, mono_window)

        # bitwise: storm requests run to identical completion in both
        # engines; the cancelled decoders compare over the common prefix
        # (slot isolation makes decode history schedule-independent)
        storm_ids = bulk_ids(0) + inter_ids(0)
        bitwise = all(
            storm_run["results"][rid].status == RequestStatus.FINISHED
            and mono_run["results"][rid].status == RequestStatus.FINISHED
            and storm_run["results"][rid].tolist()
            == mono_run["results"][rid].tolist()
            for rid in storm_ids
        )
        for r in decoders:
            a = storm_run["results"][r.request_id].tolist()
            b = mono_run["results"][r.request_id].tolist()
            n = min(len(a), len(b))
            bitwise = bitwise and n > 0 and a[:n] == b[:n]
        leaked = max(
            free0["chunked"] - chunked.pool.free_blocks,
            free0["mono"] - mono.pool.free_blocks,
        )

        c_ttft = [storm_run["ttft"][rid] * 1e3 for rid in inter_ids(0)]
        m_ttft = [mono_run["ttft"][rid] * 1e3 for rid in inter_ids(0)]
        itl_free = _pct(free_run["itl"], 0.95) * 1e3
        itl_storm = _pct(storm_run["itl"], 0.95) * 1e3
        passes.append(
            {
                "bitwise": bitwise,
                "leaked_blocks": leaked,
                "lane_preemptions": lane_preempts,
                "bulk_preemptions": sum(
                    storm_run["results"][rid].preemptions
                    for rid in bulk_ids(0)
                ),
                "chunked_ttft_p50_ms": _pct(c_ttft, 0.50),
                "chunked_ttft_p95_ms": _pct(c_ttft, 0.95),
                "monolithic_ttft_p50_ms": _pct(m_ttft, 0.50),
                "monolithic_ttft_p95_ms": _pct(m_ttft, 0.95),
                "ttft_p95_speedup": _pct(m_ttft, 0.95)
                / max(1e-9, _pct(c_ttft, 0.95)),
                "storm_free_itl_p95_ms": itl_free,
                "chunked_storm_itl_p95_ms": itl_storm,
                "monolithic_storm_itl_max_ms": (
                    max(mono_run["itl"]) * 1e3 if mono_run["itl"] else 0.0
                ),
                "chunked_bulk_ttft_p50_ms": _pct(
                    [storm_run["ttft"][rid] * 1e3 for rid in bulk_ids(0)],
                    0.50,
                ),
                "monolithic_bulk_ttft_p50_ms": _pct(
                    [mono_run["ttft"][rid] * 1e3 for rid in bulk_ids(0)],
                    0.50,
                ),
                "itl_p95_vs_storm_free": itl_storm / max(1e-9, itl_free),
            }
        )

    by_ratio = sorted(passes, key=lambda p: p["itl_p95_vs_storm_free"])
    median = by_ratio[len(by_ratio) // 2]
    invariant = ("bitwise", "leaked_blocks", "lane_preemptions")
    return {
        "slots": slots,
        "max_len": max_len,
        "decoders": n_decoders,
        "ramp_steps": ramp_steps,
        "window_steps": window,
        "bulk_requests": n_bulk,
        "bulk_prompt_tokens": bulk_prompt,
        "interactive_requests": len(inter_offsets),
        "prefill_chunk": prefill_chunk,
        "token_budget": prefill_chunk,
        "repeats": repeats,
        # invariants must hold on EVERY pass, not just the reported one
        "bitwise_identical_to_monolithic": all(p["bitwise"] for p in passes),
        "leaked_blocks": max(p["leaked_blocks"] for p in passes),
        "lane_preemptions": min(p["lane_preemptions"] for p in passes),
        "ttft_speedup_runs": [p["ttft_p95_speedup"] for p in passes],
        "itl_ratio_runs": [p["itl_p95_vs_storm_free"] for p in passes],
        **{k: v for k, v in median.items() if k not in invariant},
    }


# ------------------------------------------------- decode-step scaling phase


def _steady_engine(cfg, params, scfg, n_slots: int, fill: int, budget: int):
    """An engine with ``n_slots`` occupied slots whose caches hold ``fill``
    live tokens, warmed past admission and two decode steps."""
    from repro.serve.engine import Engine, Request

    rng = np.random.default_rng(fill)
    eng = Engine(cfg, params, scfg)
    for i in range(n_slots):
        eng.submit(
            Request(
                rng.integers(0, cfg.vocab, fill).astype(np.int32),
                max_new_tokens=budget,
                request_id=i,
            )
        )
    eng.step()  # admission + first decode (compiles)
    eng.step()  # warm steady-state decode
    return eng


def _time_steps(eng, n_steps: int) -> float:
    ts = []
    for _ in range(n_steps):
        t = time.perf_counter()
        eng.step()
        ts.append(time.perf_counter() - t)
    return _pct(ts, 0.50) * 1e3


def bench_decode_scaling(
    cfg, params, slots: int, max_len: int, seed: int, n_steps: int = 12
) -> dict:
    """Decode-step p50 latency (a) vs cache fill at full occupancy, per
    attention substrate, and (b) vs slot occupancy (flash).  Flash step
    time must grow with fill; the oracle scans max_len regardless."""
    from repro.serve.engine import ServeConfig

    fills = [max_len // 16, max_len // 4, max_len - 16]
    out: dict = {"max_len": max_len, "fills": fills, "by_fill": {}}
    for attention in ("flash", "xla"):
        res = {}
        for fill in fills:
            scfg = ServeConfig(
                batch=slots, max_len=max_len, seed=seed, attention=attention
            )
            eng = _steady_engine(cfg, params, scfg, slots, fill, n_steps + 4)
            res[str(fill)] = _time_steps(eng, n_steps)
        out["by_fill"][attention] = res
    occ = {}
    scfg = ServeConfig(batch=slots, max_len=max_len, seed=seed)
    for k in range(1, slots + 1):
        eng = _steady_engine(cfg, params, scfg, k, max_len // 4, n_steps + 4)
        occ[str(k)] = _time_steps(eng, n_steps)
    out["by_occupancy_flash"] = occ
    out["substrate"] = bench_substrate_scaling()
    return out


def bench_substrate_scaling(
    slots: int = 8,
    S: int = 4096,
    KV: int = 8,
    G: int = 4,
    d: int = 128,
    reps: int = 5,
) -> dict:
    """Attention-op-only timing at a serving-sized cache shape (the smoke
    engine's decode step is fixed-overhead dominated, so the live-length
    claim is isolated here): flash-decoding cost must track the live
    length; the masked oracle scans all ``max_len`` slots regardless.
    fp32 on purpose — CPU bf16 is software-emulated and its conversion
    cost would drown the memory-traffic signal this phase measures."""
    import jax
    import jax.numpy as jnp

    from repro.arch.attention import dense_attention
    from repro.kernels.flash_attention.ops import decode_attention

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (slots, KV, G, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (slots, S, KV, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (slots, S, KV, d), jnp.float32)

    flash = jax.jit(lambda q, k, v, n: decode_attention(q, k, v, n))
    idx = jnp.arange(S, dtype=jnp.int32)[None, :]

    def oracle_fn(q, k, v, n):
        k_pos = jnp.where(idx < n[:, None], idx, 10**9)
        return dense_attention(
            q[:, None], k, v, q_pos=n[:, None] - 1, k_pos=k_pos, causal=True
        )

    oracle = jax.jit(oracle_fn)
    res: dict = {"S": S, "shape": [slots, KV, G, d], "flash_us": {}, "oracle_us": {}}
    for frac in (16, 4, 1):
        n = jnp.full((slots,), S // frac, jnp.int32)
        for name, fn in (("flash_us", flash), ("oracle_us", oracle)):
            fn(q, k, v, n).block_until_ready()  # compile + warm
            ts = []
            for _ in range(reps):
                t = time.perf_counter()
                fn(q, k, v, n).block_until_ready()
                ts.append(time.perf_counter() - t)
            res[name][str(S // frac)] = _pct(ts, 0.5) * 1e6
    return res


# ------------------------------------------------------------ autotune phase


def _spearman(a: list[float], b: list[float]) -> float:
    """Spearman rank correlation (average ranks over ties)."""

    def ranks(xs):
        order = sorted(range(len(xs)), key=lambda i: xs[i])
        r = [0.0] * len(xs)
        i = 0
        while i < len(order):
            j = i
            while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
                j += 1
            avg = (i + j) / 2.0
            for k in range(i, j + 1):
                r[order[k]] = avg
            i = j + 1
        return r

    ra, rb = ranks(a), ranks(b)
    ma = sum(ra) / len(ra)
    mb = sum(rb) / len(rb)
    num = sum((x - ma) * (y - mb) for x, y in zip(ra, rb))
    da = sum((x - ma) ** 2 for x in ra) ** 0.5
    db = sum((y - mb) ** 2 for y in rb) ** 0.5
    return num / (da * db) if da and db else 0.0


def _autotune_grid(max_len: int, kv_budget_tokens: int):
    """>= 8 measurable configs spanning the planner's knobs: slot counts,
    both KV layouts, block sizes, and chunked admission — every member
    inside the same iso-HBM KV budget the planner sweeps under."""
    from repro.core.serveplan import ServeKnobs

    nb = lambda bs: kv_budget_tokens // bs + 1
    return [
        ServeKnobs(slots=2, kv_layout="contiguous", block_size=16),
        ServeKnobs(slots=4, kv_layout="contiguous", block_size=16),
        ServeKnobs(slots=8, kv_layout="contiguous", block_size=16),
        ServeKnobs(slots=16, kv_layout="contiguous", block_size=16),
        ServeKnobs(slots=16, kv_layout="paged", block_size=8,
                   num_blocks=nb(8)),
        ServeKnobs(slots=16, kv_layout="paged", block_size=16,
                   num_blocks=nb(16)),
        ServeKnobs(slots=16, kv_layout="paged", block_size=32,
                   num_blocks=nb(32)),
        ServeKnobs(slots=4, kv_layout="paged", block_size=16,
                   num_blocks=nb(16)),
        ServeKnobs(slots=16, kv_layout="paged", block_size=16,
                   num_blocks=nb(16), prefill_chunk=16, token_budget=16),
        ServeKnobs(slots=8, kv_layout="paged", block_size=16,
                   num_blocks=nb(16), prefill_chunk=16, token_budget=32),
    ]


def bench_autotune(
    cfg,
    params,
    seed: int,
    repeats: int = 3,
    max_len: int = 64,
    n_requests: int = 24,
) -> dict:
    """Closed-loop validation of the DSE serve planner (core/serveplan.py).

    Phase A — rank agreement: price a grid of >= 8 real configs with the
    analytic decode-step model (calibrated ONCE from two measured anchor
    configs at different occupancies), measure every config's tokens/s on
    the live engine, and check the model's top-1 pick lands in the measured
    top-3 (plus Spearman rho over the full grid for color).

    Phase B — A/B: run the planner over its full joint space under the same
    iso-HBM budget, build the winning ServeConfig, and pair it against the
    shipped default (slots=4, contiguous) on identical workloads; gate
    autotuned >= 1.0x default tokens/s.
    """
    from repro.core.serveplan import (
        Calibration,
        ServeWorkload,
        plan_serve,
        price_decode_step,
    )
    from repro.serve.engine import Engine, ServeConfig

    prompt_len, decode_len = 8, 12
    wl = ServeWorkload(
        concurrency=n_requests, prompt_len=prompt_len, decode_len=decode_len
    )
    kv_budget_tokens = 16 * max_len  # the largest grid member's footprint
    grid = _autotune_grid(max_len, kv_budget_tokens)

    def mk_requests(id_base: int):
        from repro.serve.engine import Request

        rng = np.random.default_rng(seed)
        return [
            Request(
                prompt=rng.integers(0, cfg.vocab, prompt_len).astype(
                    np.int32
                ),
                max_new_tokens=decode_len,
                request_id=id_base + i,
            )
            for i in range(n_requests)
        ]

    def measure(scfg, id_base: int) -> float:
        """Median-of-repeats tokens/s for one config on the fixed
        workload, warmed so compiles never land in a timed window."""
        with Engine(cfg, params, scfg) as eng:
            eng.run(mk_requests(id_base))  # warm every jit trace
            rates = []
            for r in range(repeats):
                reqs = mk_requests(id_base + (r + 1) * 100)
                t0 = time.perf_counter()
                outs = eng.run(reqs)
                dt = time.perf_counter() - t0
                rates.append(sum(len(o) for o in outs) / dt)
        return sorted(rates)[len(rates) // 2]

    measured = [
        measure(
            ServeConfig.from_plan_knobs(k, max_len=max_len, seed=seed),
            50_000 + i * 1000,
        )
        for i, k in enumerate(grid)
    ]
    costs = [
        price_decode_step(cfg, k, max_len=max_len, workload=wl) for k in grid
    ]
    assert all(c is not None for c in costs), "grid must be feasible"

    # calibrate once from four anchors spanning the fitted features — two
    # contiguous occupancies (overhead + per-row), one paged member
    # (per-gathered-block), one chunked member (lane dispatch) — then rank
    # everything else with the same terms
    anchors = [0, 3, 5, 8]
    calib = Calibration.fit(
        [(costs[i], costs[i].rows / measured[i]) for i in anchors]
    )
    predicted = [c.tokens_per_s(calib) for c in costs]
    pred_top1 = max(range(len(grid)), key=lambda i: predicted[i])
    meas_rank = sorted(
        range(len(grid)), key=lambda i: measured[i], reverse=True
    )
    top1_in_top3 = pred_top1 in meas_rank[:3]
    rho = _spearman(predicted, measured)

    # phase B: full-space planner winner vs the shipped default
    plan = plan_serve(
        cfg,
        max_len=max_len,
        workload=wl,
        kv_budget_tokens=kv_budget_tokens,
        calibration=calib,
        cache=False,
    )
    tuned_cfg = ServeConfig.from_plan_knobs(
        plan.knobs, max_len=max_len, seed=seed
    )
    default_cfg = ServeConfig(max_len=max_len, seed=seed)
    tuned = measure(tuned_cfg, 80_000)
    default = measure(default_cfg, 90_000)

    return {
        "max_len": max_len,
        "requests": n_requests,
        "prompt_len": prompt_len,
        "decode_len": decode_len,
        "kv_budget_tokens": kv_budget_tokens,
        "grid_size": len(grid),
        "grid": [
            {
                "knobs": dataclasses.asdict(k),
                "measured_tokens_per_s": m,
                "predicted_tokens_per_s": p,
            }
            for k, m, p in zip(grid, measured, predicted)
        ],
        "calibration": {
            "anchors": anchors,
            **dataclasses.asdict(calib),
        },
        "predicted_top1": pred_top1,
        "measured_top3": meas_rank[:3],
        "rank_agreement_top1_in_top3": top1_in_top3,
        "spearman_rho": rho,
        "planned_knobs": dataclasses.asdict(plan.knobs),
        "plan_predicted_tokens_per_s": plan.predicted["tokens_per_s"],
        "plan_swept_points": plan.predicted["swept_points"],
        "autotuned_tokens_per_s": tuned,
        "default_tokens_per_s": default,
        "autotuned_vs_default_tokens_per_s": tuned / default,
    }


# ----------------------------------------------------------------- top level


def run(
    arch: str = "smollm-360m-smoke",
    slots: int = 4,
    max_len: int = 64,
    n_requests: int = 20,
    seed: int = 0,
    repeats: int = 3,
    out_path: str | None = "BENCH_serve.json",
    scaling: bool = True,
    ab: bool = True,
    paged: bool = True,
    fault_storm: bool = True,
    crash_recovery: bool = True,
    admission_storm: bool = True,
    sdc: bool = True,
    autotune: bool = True,
    # serving-sized cache for the substrate A/B: at the smoke models' tiny
    # dims the decode step is fixed-overhead dominated, so the oracle's
    # max_len scan only becomes visible at a real cache extent
    ab_max_len: int = 1024,
) -> dict:
    import jax

    from repro.arch.model_zoo import build
    from repro.core.mapper import choose_matmul_tiles
    from repro.serve.engine import Engine, ServeConfig, StaticEngine

    cfg = get_cfg(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(
        batch=slots,
        max_len=max_len,
        temperature=0.0,
        seed=seed,
        prefill_bucket=16,
    )

    cont = Engine(cfg, params, scfg)
    stat = StaticEngine(cfg, params, scfg)

    # warmup: identical shapes, separate ids -> every jit trace (admission
    # group sizes, decode, the n=1 solo probe) is cached before any timed
    # pass, so the A/B measures scheduling, not compiles
    warm = make_workload(cfg.vocab, n_requests, seed, id_base=10_000)
    cont.run(warm)
    cont.run(make_workload(cfg.vocab, n_requests, seed, id_base=20_000)[:1])
    stat.generate(warm)

    continuous, static, sched_ratio = _paired_ab(
        lambda rs, cb: cont.run(rs, on_token=cb),
        lambda rs, cb: stat.generate(rs, on_token=cb),
        lambda r, side: make_workload(
            cfg.vocab, n_requests, seed, id_base=r * 1000 if side == 0 else 0
        ),
        repeats,
    )

    # correctness evidence: a sample of batched outputs must equal their
    # solo (single-request) runs bitwise — slot isolation on real traffic.
    # (Static outputs are NOT compared: StaticEngine left-pads without
    # masking, so its context genuinely differs; that quality loss is part
    # of what continuous batching removes.)
    batched_outs = continuous.pop("outputs")
    static.pop("outputs")
    solo_ok = True
    for j in range(0, n_requests, max(1, n_requests // 4)):
        probe = make_workload(cfg.vocab, n_requests, seed, id_base=90_000 + j)[j]
        solo = cont.run([probe])[0]
        solo_ok = solo_ok and solo.tolist() == batched_outs[j]

    # attention substrate A/B at a serving-sized cache: same scheduler,
    # same workload — the delta is ragged flash-decoding vs the masked
    # dense/blockwise oracle scanning max_len slots every step
    ab_res = {}
    ab_ratio = None
    if ab:
        engines = {}
        for attention in ("flash", "xla"):
            engines[attention] = Engine(
                cfg,
                params,
                ServeConfig(
                    batch=slots,
                    max_len=ab_max_len,
                    seed=seed,
                    prefill_bucket=16,
                    attention=attention,
                ),
            )
            engines[attention].run(
                make_workload(cfg.vocab, n_requests, seed, id_base=30_000)
            )
        fl, xl, ab_ratio = _paired_ab(
            lambda rs, cb: engines["flash"].run(rs, on_token=cb),
            lambda rs, cb: engines["xla"].run(rs, on_token=cb),
            lambda r, side: make_workload(
                cfg.vocab,
                n_requests,
                seed,
                id_base=40_000 + r * 2000 + side * 1000,
            ),
            repeats,
        )
        fl.pop("outputs")
        xl.pop("outputs")
        ab_res = {"flash": fl, "xla": xl}

    tiles = choose_matmul_tiles(slots, cfg.vocab, cfg.d_model)
    result = {
        "arch": arch,
        "slots": slots,
        "max_len": max_len,
        "requests": n_requests,
        "prompt_len_range": [3, 16],
        "max_new_range": [4, 20],
        "continuous": continuous,
        "static": static,
        "speedup_tokens_per_s": sched_ratio,
        "solo_outputs_identical": solo_ok,
        "decode_unembed_tiles": dataclass_tuple(tiles),
    }
    if ab:
        result["attention_ab"] = {
            "max_len": ab_max_len,
            "flash": ab_res["flash"],
            "oracle": ab_res["xla"],
            "flash_vs_oracle_speedup": ab_ratio,
        }
    if paged:
        result["paged"] = bench_paged(cfg, params, slots, seed, n_requests)
    if fault_storm:
        result["fault_storm"] = bench_fault_storm(cfg, params, slots, seed)
    if crash_recovery:
        result["crash_recovery"] = bench_crash_recovery(
            cfg, params, slots, seed
        )
    if admission_storm:
        result["admission_storm"] = bench_admission_storm(cfg, params, seed)
    if sdc:
        # the ABFT price is meaningless on a dispatch-dominated smoke
        # model, so the overhead A/B runs a scaled-up variant where decode
        # steps are genuinely memory/compute bound; detection episodes
        # stay on the smoke config (their contract is exactness, not time)
        sdc_overhead_cfg = dataclasses.replace(
            cfg,
            name=f"{cfg.name}-sdc-overhead",
            n_layers=6,
            d_model=512,
            d_ff=1536,
            vocab=16384,
            n_heads=8,
            n_kv_heads=2,
            head_dim=64,
        )
        result["sdc"] = bench_sdc(
            cfg,
            params,
            slots,
            seed,
            n_requests=32,
            repeats=5,
            overhead_cfg=sdc_overhead_cfg,
            overhead_slots=32,
        )
    if autotune:
        result["autotune"] = bench_autotune(cfg, params, seed, repeats)
    if scaling:
        result["decode_step_scaling"] = bench_decode_scaling(
            cfg, params, slots, ab_max_len, seed
        )
    line = (
        f"serve: continuous {continuous['tokens_per_s']:.1f} tok/s "
        f"(itl p50 {continuous['itl_p50_ms']:.1f}ms, "
        f"p95 {continuous['itl_p95_ms']:.1f}ms) "
        f"vs static {static['tokens_per_s']:.1f} tok/s: "
        f"{result['speedup_tokens_per_s']:.2f}x"
    )
    if ab:
        line += (
            f" | flash vs oracle @ max_len={ab_max_len}: "
            f"{result['attention_ab']['flash_vs_oracle_speedup']:.2f}x"
        )
    print(line)
    if paged:
        sh = result["paged"]["shared_prefix"]
        bitwise = result["paged"]["agreement"]["bitwise_identical"]
        print(
            f"paged: agreement bitwise={bitwise} | "
            f"shared-prefix @ equal HBM: concurrency "
            f"{sh['paged']['peak_concurrent']} vs "
            f"{sh['contiguous']['peak_concurrent']} "
            f"({sh['admitted_concurrency_ratio']:.2f}x), "
            f"ttft p95 {sh['paged']['ttft_p95_ms']:.0f}ms vs "
            f"{sh['contiguous']['ttft_p95_ms']:.0f}ms"
        )
    if fault_storm:
        fs = result["fault_storm"]
        print(
            f"fault-storm: {fs['statuses']} | leaked_blocks="
            f"{fs['leaked_blocks']} | preemptions={fs['preemptions']} "
            f"recovered={fs['recovered']} "
            f"(p50 {fs['recovery_latency_p50_ms']:.0f}ms) | survivors "
            f"bitwise={fs['bitwise_survivors_match_baseline']}, "
            f"itl p95 {fs['survivor_itl_p95_ms']:.1f}ms vs no-fault "
            f"{fs['baseline']['itl_p95_ms']:.1f}ms "
            f"({fs['survivor_itl_p95_vs_baseline']:.2f}x)"
        )
    if crash_recovery:
        cr = result["crash_recovery"]
        rec = cr["recovery"]
        print(
            f"crash-recovery: snapshot ITL p95 overhead "
            f"{cr['overhead']['snapshot_itl_p95_vs_off']:.2f}x "
            f"({cr['overhead']['snapshots_taken']} snapshots, "
            f"{cr['snapshot_dir_fs']}) | restore from {rec['source']} in "
            f"{rec['restore_ms']:.0f}ms + replay {rec['tokens_replayed']} "
            f"toks in {rec['replay_catchup_ms']:.0f}ms | "
            f"bitwise={rec['bitwise_survivors']} "
            f"mismatches={rec['replay_mismatches']} "
            f"leaked={rec['leaked_blocks']}"
        )
    if admission_storm:
        st = result["admission_storm"]
        print(
            f"admission-storm: interactive ttft p95 "
            f"{st['chunked_ttft_p95_ms']:.0f}ms chunked vs "
            f"{st['monolithic_ttft_p95_ms']:.0f}ms monolithic "
            f"({st['ttft_p95_speedup']:.1f}x) | decoder itl p95 "
            f"{st['chunked_storm_itl_p95_ms']:.1f}ms vs storm-free "
            f"{st['storm_free_itl_p95_ms']:.1f}ms "
            f"({st['itl_p95_vs_storm_free']:.2f}x, mono spike "
            f"{st['monolithic_storm_itl_max_ms']:.0f}ms) | "
            f"bitwise={st['bitwise_identical_to_monolithic']} "
            f"leaked={st['leaked_blocks']} "
            f"lane_preemptions={st['lane_preemptions']}"
        )
    if sdc:
        sd = result["sdc"]
        det = sd["detection"]
        print(
            f"sdc: abft ITL p95 {sd['overhead']['abft_itl_p95_vs_off']:.2f}x "
            f"off ({sd['overhead_model']}, {sd['overhead_slots']} slots, "
            f"scrub_every={sd['scrub_every']}) | detection "
            f"{det['detected']}/{det['injected_compute']} "
            f"compute, {det['quarantined']}/{det['injected_kv']} kv | "
            f"clean false positives={sd['clean_false_positives']} "
            f"bitwise_vs_off={sd['bitwise_identical_to_off']}"
        )
    if autotune:
        at = result["autotune"]
        print(
            f"autotune: top-1 predicted #{at['predicted_top1']} in measured "
            f"top-3 {at['measured_top3']}: "
            f"{at['rank_agreement_top1_in_top3']} "
            f"(spearman {at['spearman_rho']:.2f} over "
            f"{at['grid_size']} configs) | planned "
            f"{at['planned_knobs']['slots']} slots "
            f"{at['planned_knobs']['kv_layout']}/"
            f"bs={at['planned_knobs']['block_size']}: "
            f"{at['autotuned_tokens_per_s']:.1f} tok/s vs default "
            f"{at['default_tokens_per_s']:.1f} "
            f"({at['autotuned_vs_default_tokens_per_s']:.2f}x)"
        )
    if scaling:
        sc = result["decode_step_scaling"]
        print(
            f"decode step p50 ms by fill {sc['fills']}: "
            f"flash {list(sc['by_fill']['flash'].values())} "
            f"vs oracle {list(sc['by_fill']['xla'].values())}"
        )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {out_path}")
    return result


def get_cfg(arch: str):
    from repro.configs.registry import get

    return get(arch)


def dataclass_tuple(tiles) -> list[int]:
    return [tiles.bm, tiles.bn, tiles.bk]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument(
        "--no-scaling",
        action="store_true",
        help="skip the decode-step scaling phase",
    )
    ap.add_argument(
        "--no-paged",
        action="store_true",
        help="skip the paged-vs-contiguous KV layout phase",
    )
    ap.add_argument(
        "--no-fault-storm",
        action="store_true",
        help="skip the request-lifecycle fault-storm phase",
    )
    ap.add_argument(
        "--no-crash-recovery",
        action="store_true",
        help="skip the snapshot-overhead + kill/restore drill phase",
    )
    ap.add_argument(
        "--no-admission-storm",
        action="store_true",
        help="skip the chunked-vs-monolithic admission-storm phase",
    )
    ap.add_argument(
        "--no-sdc",
        action="store_true",
        help="skip the ABFT overhead + seeded bit-flip detection phase",
    )
    ap.add_argument(
        "--no-autotune",
        action="store_true",
        help="skip the DSE-planner rank-agreement + autotuned-vs-default "
        "phase",
    )
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    run(
        arch=args.arch,
        slots=args.slots,
        max_len=args.max_len,
        n_requests=args.requests,
        seed=args.seed,
        repeats=args.repeats,
        out_path=args.out,
        scaling=not args.no_scaling,
        paged=not args.no_paged,
        fault_storm=not args.no_fault_storm,
        crash_recovery=not args.no_crash_recovery,
        admission_storm=not args.no_admission_storm,
        sdc=not args.no_sdc,
        autotune=not args.no_autotune,
    )


if __name__ == "__main__":
    main()
