"""Continuous vs static batching on a mixed-length synthetic workload.

Measures tokens/sec and per-token latency (p50/p95) for the slot-based
continuous-batching engine against the padded static-batch baseline at
EQUAL batch slots, and emits BENCH_serve.json:

    PYTHONPATH=src python -m benchmarks.serve_bench [--requests N] [--out F]

Both engines run the same jitted prefill/decode programs; the delta is
pure scheduling: static batching pads every request to the slowest prompt
and the largest max_new_tokens in its batch, continuous batching backfills
a slot the moment its request finishes (the paper's utilization argument,
Interstellar §6.3, at request granularity).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def make_workload(vocab: int, n: int, seed: int, id_base: int = 0):
    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(0, vocab, rng.integers(3, 17)).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 21)),
            request_id=id_base + i,
        )
        for i in range(n)
    ]


def _latency_stats(stamps: dict[int, list[float]]) -> dict[str, float]:
    """Per-token latency: first token from arrival (t=0 for the whole
    open-loop workload), then inter-token gaps."""
    deltas = sorted(
        b - a
        for ts in stamps.values()
        for a, b in zip([0.0] + ts[:-1], ts)
    )
    if not deltas:
        return {"p50_ms": 0.0, "p95_ms": 0.0}
    return {
        "p50_ms": deltas[len(deltas) // 2] * 1e3,
        "p95_ms": deltas[min(len(deltas) - 1, int(len(deltas) * 0.95))] * 1e3,
    }


def _drive(run_fn, requests) -> dict:
    stamps: dict[int, list[float]] = {}
    t0 = time.perf_counter()

    def on_token(rid, tok, idx, done):
        stamps.setdefault(rid, []).append(time.perf_counter() - t0)

    outs = run_fn(requests, on_token)
    wall = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    return {
        "tokens": total,
        "wall_s": wall,
        "tokens_per_s": total / wall,
        **_latency_stats(stamps),
        "outputs": [o.tolist() for o in outs],
    }


def run(
    arch: str = "smollm-360m-smoke",
    slots: int = 4,
    max_len: int = 64,
    n_requests: int = 20,
    seed: int = 0,
    repeats: int = 3,
    out_path: str | None = "BENCH_serve.json",
) -> dict:
    import jax

    from repro.arch.model_zoo import build
    from repro.core.mapper import choose_matmul_tiles
    from repro.serve.engine import Engine, ServeConfig, StaticEngine

    cfg = get_cfg(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(
        batch=slots,
        max_len=max_len,
        temperature=0.0,
        seed=seed,
        prefill_bucket=16,
    )

    cont = Engine(cfg, params, scfg)
    stat = StaticEngine(cfg, params, scfg)

    # warmup: identical shapes, separate ids -> every jit trace is cached
    # before the timed pass, so the A/B measures scheduling, not compiles
    warm = make_workload(cfg.vocab, n_requests, seed, id_base=10_000)
    cont.run(warm)
    stat.generate(warm)

    # best-of-N: the timed window is a fraction of a second, so a single
    # pass is at the mercy of whatever else the host is doing
    continuous = static = None
    for r in range(repeats):
        reqs_c = make_workload(cfg.vocab, n_requests, seed, id_base=r * 1000)
        reqs_s = make_workload(cfg.vocab, n_requests, seed)
        c = _drive(lambda rs, cb: cont.run(rs, on_token=cb), reqs_c)
        s = _drive(lambda rs, cb: stat.generate(rs, on_token=cb), reqs_s)
        if continuous is None or c["tokens_per_s"] > continuous["tokens_per_s"]:
            continuous = c
        if static is None or s["tokens_per_s"] > static["tokens_per_s"]:
            static = s

    # correctness evidence: a sample of batched outputs must equal their
    # solo (single-request) runs bitwise — slot isolation on real traffic.
    # (Static outputs are NOT compared: StaticEngine left-pads without
    # masking, so its context genuinely differs; that quality loss is part
    # of what continuous batching removes.)
    batched_outs = continuous.pop("outputs")
    static.pop("outputs")
    solo_ok = True
    for j in range(0, n_requests, max(1, n_requests // 4)):
        probe = make_workload(cfg.vocab, n_requests, seed, id_base=90_000 + j)[j]
        solo = cont.run([probe])[0]
        solo_ok = solo_ok and solo.tolist() == batched_outs[j]
    tiles = choose_matmul_tiles(slots, cfg.vocab, cfg.d_model)
    result = {
        "arch": arch,
        "slots": slots,
        "max_len": max_len,
        "requests": n_requests,
        "prompt_len_range": [3, 16],
        "max_new_range": [4, 20],
        "continuous": continuous,
        "static": static,
        "speedup_tokens_per_s": continuous["tokens_per_s"] / static["tokens_per_s"],
        "solo_outputs_identical": solo_ok,
        "decode_unembed_tiles": dataclass_tuple(tiles),
    }
    print(
        f"serve: continuous {continuous['tokens_per_s']:.1f} tok/s "
        f"(p50 {continuous['p50_ms']:.1f}ms, p95 {continuous['p95_ms']:.1f}ms) "
        f"vs static {static['tokens_per_s']:.1f} tok/s "
        f"(p50 {static['p50_ms']:.1f}ms, p95 {static['p95_ms']:.1f}ms): "
        f"{result['speedup_tokens_per_s']:.2f}x"
    )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {out_path}")
    return result


def get_cfg(arch: str):
    from repro.configs.registry import get

    return get(arch)


def dataclass_tuple(tiles) -> list[int]:
    return [tiles.bm, tiles.bn, tiles.bk]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    run(
        arch=args.arch,
        slots=args.slots,
        max_len=args.max_len,
        n_requests=args.requests,
        seed=args.seed,
        repeats=args.repeats,
        out_path=args.out,
    )


if __name__ == "__main__":
    main()
