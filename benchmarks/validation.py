"""Fig 7 analogue: analytical model vs the exact simulator.

The paper validated its analytical model against post-synthesis ASIC designs
(<2% error).  Our oracle is the exact tile-granular simulator; agreement is
exact on divisible schedules by construction, which we demonstrate here on
the paper's own Table-4-style design points (OS4, OS8, WS16 analogues).
"""

from __future__ import annotations

from repro.core import (
    ArraySpec,
    MemLevel,
    analyze,
    conv_nest,
    evaluate,
    make_dataflow,
    simulate,
)
from repro.core.blocking import search_blocking


def table4_designs():
    """OS4/OS8 (1D output-stationary) and WS16 (2D C|K) reduced design
    points from paper Table 4, on a small CONV layer."""
    nest = conv_nest("t", B=4, K=16, C=16, X=8, Y=8, FX=3, FY=3)
    designs = []
    for name, arr_dims, primary, rf, sram in (
        ("OS4", (4,), ("X",), 32, 32 * 1024),
        ("OS8", (8,), ("X",), 64, 64 * 1024),
        ("WS16", (4, 4), ("C", "K"), 64, 32 * 1024),
    ):
        arr = ArraySpec(dims=arr_dims)
        df = make_dataflow(nest, arr, primary, replication=False)
        levels = (
            MemLevel("RF", rf, double_buffered=False, per_pe=True),
            MemLevel("BUF", sram),
            MemLevel("DRAM", None),
        )
        res = search_blocking(nest, levels, arr, df, beam=8)
        designs.append((name, res.best.schedule))
    return designs


def main():
    mismatches = []
    for name, sched in table4_designs():
        # simulator handles temporal loops; fold spatial out for the check
        import dataclasses

        from repro.core.schedule import ArraySpec as AS

        temporal = dataclasses.replace(
            sched,
            tiling={
                d: tuple(
                    f * (sched.spatial_factor(d) if i == len(sched.levels) - 1 else 1)
                    for i, f in enumerate(sched.tiling[d])
                )
                for d in sched.nest.dims
            },
            array=AS(dims=(1,)),
            spatial=((),),
        )
        s = simulate(temporal)
        a2 = analyze(temporal)
        match = a2.reads == s.reads and a2.writes == s.writes
        if not match:
            mismatches.append(name)
        rep = evaluate(sched)
        print(
            f"validation,{name},model_vs_sim={'exact' if match else 'MISMATCH'},"
            f"energy={rep.energy_pj/1e3:.1f}nJ,util={rep.utilization:.2f}"
        )
    if mismatches:
        raise RuntimeError(
            f"analytical model diverged from the exact simulator on: "
            f"{', '.join(mismatches)}"
        )


if __name__ == "__main__":
    main()
