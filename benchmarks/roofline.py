"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads experiments/dryrun/*.json (written by launch/dryrun.py) and derives,
per cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]
    collective term = collective_bytes_per_device / (links*ICI)  [s]

plus MODEL_FLOPS (analytic 6*N*D / 2*N*D + attention) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPS_total, the dominant bottleneck, and a
suggestion for what would move it.  The "roofline fraction" reported in
EXPERIMENTS.md §Perf is compute_term / max(all terms): 1.0 means perfectly
compute-bound (the roofline ideal for these workloads).

Hardware constants (TPU v5e, from the task sheet): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI; we assume 2 usable ICI links per chip
(one ring per mesh axis of the 2D torus).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import get

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_PER_LINK = 50e9
ICI_LINKS = 2


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (all devices)."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    N = cfg.active_params_count()
    d_attn = cfg.n_heads * cfg.resolved_head_dim
    S, B = shape.seq_len, shape.global_batch

    # attention context FLOPs (QK^T + PV = 4 * tokens * kv_len * d_attn),
    # causal prefill halves kv_len on average; window layers clamp it.
    def attn_flops(tokens: int, kv_len: float) -> float:
        n_attn = cfg.n_layers if cfg.mixer == "attention" else 0
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // (cfg.rnn_per_attention + 1)
        win = cfg.sliding_window
        if cfg.global_every and win:
            ge = cfg.global_every
            n_glob = cfg.n_layers // ge
            n_loc = n_attn - n_glob
            return 4.0 * tokens * d_attn * (
                n_glob * kv_len + n_loc * min(kv_len, win)
            )
        if win:
            kv_len = min(kv_len, win)
        return 4.0 * tokens * d_attn * n_attn * kv_len

    if shape.kind == "train":
        tokens = B * S
        f = 6.0 * N * tokens + 3.0 * attn_flops(tokens, S / 2)
    elif shape.kind == "prefill":
        tokens = B * S
        f = 2.0 * N * tokens + attn_flops(tokens, S / 2)
        if cfg.family == "encdec":
            f += 2.0 * N * B * cfg.encoder_seq
    else:  # decode: one token per sequence
        tokens = B
        f = 2.0 * N * tokens + attn_flops(tokens, S)
        if cfg.family == "encdec":
            f += 4.0 * tokens * d_attn * cfg.n_layers * cfg.encoder_seq
    return f


def hlo_costs(rec: dict, json_path: str) -> dict | None:
    """Exact per-device totals from the .hlo.gz sidecars via the
    hierarchical cost parser (benchmarks/hlo_cost.py); memoized into the
    record file under 'hlo_cost'."""
    if "hlo_cost" in rec:
        return rec["hlo_cost"]
    from benchmarks.hlo_cost import cost_of_file

    c1p = json_path.replace(".json", ".c1.hlo.gz")
    c2p = json_path.replace(".json", ".c2.hlo.gz")
    if not (os.path.exists(c1p) and os.path.exists(c2p)):
        return None
    c1, c2 = cost_of_file(c1p), cost_of_file(c2p)
    units = rec["scan_units"]
    out = {
        "flops": c1["flops"] + (c2["flops"] - c1["flops"]) * (units - 1),
        "bytes": c1["bytes"] + (c2["bytes"] - c1["bytes"]) * (units - 1),
        "coll": {
            k: c1["coll"][k] + (c2["coll"][k] - c1["coll"][k]) * (units - 1)
            for k in c1["coll"]
        },
    }
    rec["hlo_cost"] = out
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    return out


def analyze_record(rec: dict, json_path: str | None = None) -> dict:
    n_dev = rec["n_devices"]
    hc = hlo_costs(rec, json_path) if json_path else rec.get("hlo_cost")
    if hc:
        flops_dev = hc["flops"]
        bytes_dev = hc["bytes"]
        coll_dev = hc["coll"]["total"]
    else:  # fall back to the (scan-body-once) XLA numbers
        flops_dev = rec["cost_per_device"]["flops"]
        bytes_dev = rec["cost_per_device"]["bytes"]
        coll_dev = rec["collective_bytes_per_device"]["total"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / (ICI_LINKS * ICI_PER_LINK)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=lambda k: terms[k])
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * n_dev
    ratio = mf / hlo_total if hlo_total else float("nan")
    frac = compute_s / max(max(terms.values()), 1e-30)
    suggestion = {
        "compute": "compute-bound: reduce recompute (remat policy) or pad "
                   "waste; already near roofline",
        "memory": "HBM-bound: increase arithmetic intensity (bigger tiles, "
                  "fused kernels, larger per-device batch)",
        "collective": "ICI-bound: reshard to cut gather/reduce volume, "
                      "overlap collectives with compute, or compress",
    }[dom]
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dom,
        "roofline_fraction": frac,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": ratio,
        "peak_gib": rec["memory"]["peak_estimate_bytes"] / 2**30,
        "suggestion": suggestion,
    }


def load_all(
    dryrun_dir: str = "experiments/dryrun", include_variants: bool = False
) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not include_variants and (rec.get("overrides") or rec.get("rules")):
            continue  # §Perf variant records: baselines only by default
        out.append(analyze_record(rec, path))
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bound | roofline frac | useful ratio | peak GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['peak_gib']:.2f} |\n"
        )
    return hdr + body


def pick_hillclimb_cells(rows: list[dict]) -> dict[str, dict]:
    """worst roofline fraction / most collective-bound / paper-representative
    (the MoE train cell: dataflow-choice = expert placement, the paper's
    spatial-unrolling question at pod scale)."""
    single = [r for r in rows if r["mesh"] == "16x16"]
    worst = min(single, key=lambda r: r["roofline_fraction"])
    coll = max(single, key=lambda r: r["collective_s"])
    rep = next(
        (r for r in single
         if r["arch"] == "grok-1-314b" and r["shape"] == "train_4k"),
        single[0],
    )
    return {"worst_roofline": worst, "most_collective": coll,
            "paper_representative": rep}


def main():
    rows = load_all()
    if not rows:
        print("roofline,no_dryrun_records_found")
        return
    print(markdown_table(rows))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_baseline.md", "w") as f:
        f.write(markdown_table(rows))
    picks = pick_hillclimb_cells(rows)
    for tag, r in picks.items():
        print(
            f"hillclimb_pick,{tag},{r['arch']},{r['shape']},"
            f"dominant={r['dominant']},frac={r['roofline_fraction']:.2f}"
        )


if __name__ == "__main__":
    main()
