"""Hierarchical HLO cost analyzer - the dry-run "profiler".

XLA's python cost_analysis() counts every while-loop body ONCE, which
under-counts programs with nested scans (microbatch x layers x attention
blocks x recurrence chunks) by orders of magnitude.  This module parses the
compiled (post-SPMD, per-device) HLO text and rolls costs up through the
call graph with loop trip counts:

  flops:   dot = 2 * |result| * prod(lhs contracting dims)
           elementwise arithmetic = |result|   (counts RWKV/RG-LRU work)
           reduce/reduce-window = |operand|
  bytes:   2 x result bytes per top-level op (one write + one subsequent
           read; operands are some producer's result, so counting results
           only avoids double counting).  Fusion interfaces count, fusion
           internals do not - each fusion is one HBM-roundtrip kernel.
           This is an HBM-traffic model: every inter-kernel tensor round-
           trips HBM, which is how TPUs execute non-fused kernels.
  coll:    result bytes per collective (x2 for all-reduce), same rollup

  while(cond, body):  body cost x trip count; trip = max int constant in
                      the cond computation (jax scans compare a counter
                      against that constant)
  fusion:  adds the fused computation's FLOPs (its ops execute) but not its
           internal traffic
  call / conditional: full cost (conditional: max over branches)

Used by benchmarks/roofline.py on the .hlo.gz sidecars the dry-run writes;
the same A/B (1-layer / 2-layer) reconstruction then scales to the full
depth exactly.
"""

from __future__ import annotations

import gzip
import re

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}: ]+?)\s)?([a-z][\w\-]*)\(")
# param lists may contain nested parens (tuple-typed args): match greedily
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s+\(.*\)\s+->\s+.*\{")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "sqrt", "rsqrt", "negate", "abs",
    "select", "compare", "and", "or", "xor", "exponential-minus-one",
    "log-plus-one", "floor", "ceil", "round-nearest-afz", "sign",
    "logistic", "cbrt", "atan2", "remainder", "clamp",
}

PLUMBING = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(segment: str) -> tuple[int, int]:
    elems = nbytes = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES[dt]
    return elems, nbytes


class HloCost:
    def __init__(self, text: str):
        self.comps: dict[str, list[str]] = {}
        self._entry = None
        self._parse_computations(text)
        self._shape_of: dict[tuple[str, str], str] = {}
        self._index_shapes()
        self._memo: dict[str, dict] = {}

    # ------------------------------------------------------------- parsing --
    def _parse_computations(self, text: str):
        cur, buf = None, []
        for line in text.splitlines():
            m = _COMP_HEAD.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1).lstrip("%")
                if line.lstrip().startswith("ENTRY"):
                    self._entry = cur
                buf = []
                self.comps[cur] = buf
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                buf.append(line.rstrip())

    def _index_shapes(self):
        for cname, lines in self.comps.items():
            for line in lines:
                m = _INSTR_RE.match(line)
                if not m:
                    continue
                name, rhs = m.group(1), m.group(2)
                om = _OPCODE_RE.match(rhs)
                if not om:
                    continue
                result_part = om.group(1) or ""
                self._shape_of[(cname, name)] = result_part

    # -------------------------------------------------------------- costs --
    def _trip_count(self, cond_comp: str) -> int:
        best = 1
        for line in self.comps.get(cond_comp, ()):
            for c in re.findall(r"constant\((\d+)\)", line):
                best = max(best, int(c))
        return best

    def _operand_bytes(self, cname: str, rhs: str, opcode: str) -> int:
        """Bytes of named operands (looked up in the computation) plus any
        inline-typed operands."""
        call = rhs[rhs.index(opcode) + len(opcode):]
        # take the top-level parenthesized arg list
        depth = 0
        args = ""
        for ch in call:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        total = 0
        # inline shapes in the arg list
        _, b = _shape_elems_bytes(args)
        total += b
        # named operands
        for nm in re.findall(r"%[\w.\-]+", args):
            seg = self._shape_of.get((cname, nm))
            if seg:
                _, bb = _shape_elems_bytes(seg)
                total += bb
        return total

    def computation_cost(self, cname: str) -> dict:
        if cname in self._memo:
            return self._memo[cname]
        flops = 0.0
        nbytes = 0.0
        coll = {c: 0.0 for c in COLLECTIVES}
        self._memo[cname] = {
            "flops": 0.0, "bytes": 0.0, "coll": dict(coll)
        }  # cycle guard
        for line in self.comps.get(cname, ()):
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            om = _OPCODE_RE.match(rhs)
            if not om:
                continue
            result_part, opcode = om.group(1) or "", om.group(2)
            res_elems, res_bytes = _shape_elems_bytes(result_part)

            if opcode == "while":
                cm = re.search(r"condition=(%[\w.\-]+), body=(%[\w.\-]+)", rhs)
                if cm:
                    trip = self._trip_count(cm.group(1).lstrip("%"))
                    sub = self.computation_cost(cm.group(2).lstrip("%"))
                    flops += trip * sub["flops"]
                    nbytes += trip * sub["bytes"]
                    for c in COLLECTIVES:
                        coll[c] += trip * sub["coll"][c]
                continue
            if opcode == "fusion":
                cm = re.search(r"calls=(%[\w.\-]+)", rhs)
                if cm:
                    sub = self.computation_cost(cm.group(1).lstrip("%"))
                    flops += sub["flops"]  # internal flops execute
                nbytes += 2 * res_bytes
                continue
            if opcode in ("call", "async-start"):
                cm = re.search(r"to_apply=(%[\w.\-]+)", rhs)
                if cm:
                    sub = self.computation_cost(cm.group(1).lstrip("%"))
                    flops += sub["flops"]
                    nbytes += sub["bytes"]
                    for c in COLLECTIVES:
                        coll[c] += sub["coll"][c]
                continue
            if opcode == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if branches:
                    subs = [
                        self.computation_cost(b.strip().lstrip("%"))
                        for b in branches.group(1).split(",")
                    ]
                    if subs:
                        best = max(subs, key=lambda s: s["flops"])
                        flops += best["flops"]
                        nbytes += best["bytes"]
                continue

            base = opcode.replace("-start", "")
            if base in COLLECTIVES:
                mult = 2.0 if base == "all-reduce" else 1.0
                coll[base] += mult * res_bytes
                nbytes += res_bytes
                continue
            if opcode in PLUMBING or opcode.endswith("-done"):
                continue

            if opcode == "dot":
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                lhs_nm = re.findall(r"%[\w.\-]+", rhs.split("dot(", 1)[1])
                lhs_seg = (
                    self._shape_of.get((cname, lhs_nm[0])) if lhs_nm else None
                )
                if cm and lhs_seg:
                    dims_m = _SHAPE_RE.search(lhs_seg)
                    if dims_m:
                        lhs_dims = [
                            int(d) for d in dims_m.group(2).split(",") if d
                        ]
                        for idx in cm.group(1).split(","):
                            if idx:
                                contract *= lhs_dims[int(idx)]
                flops += 2.0 * res_elems * contract
            elif opcode in ELEMENTWISE:
                flops += res_elems
            elif opcode in ("reduce", "reduce-window"):
                ob = self._operand_bytes(cname, rhs, opcode)
                flops += ob / 4.0  # ~1 flop per operand element (fp32-ish)
                nbytes += ob  # reductions read far more than they write

            nbytes += 2 * res_bytes

        out = {"flops": flops, "bytes": nbytes, "coll": coll}
        self._memo[cname] = out
        return out

    def entry_cost(self) -> dict:
        assert self._entry, "no ENTRY computation found"
        c = self.computation_cost(self._entry)
        c = dict(c)
        c["coll"] = dict(c["coll"])
        c["coll"]["total"] = sum(c["coll"][k] for k in COLLECTIVES)
        return c


def cost_of_file(path: str) -> dict:
    with gzip.open(path, "rt") as f:
        return HloCost(f.read()).entry_cost()
